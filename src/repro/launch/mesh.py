"""Production mesh definitions and the process-aware mesh descriptor.

A TPU v5e pod slice of 256 chips is modelled as a (data=16, model=16) mesh;
the two-pod production job adds a leading "pod" axis: (2, 16, 16).  Data
parallelism (and FSDP param sharding) runs over ("pod", "data"); tensor /
expert parallelism over "model".  Functions, not module constants — importing
this module never touches jax device state.

Multi-host topology lives in :class:`ProcessMesh`: which process owns which
data shards of a global mesh, which rows of a global batch this process must
feed, and how to assemble a globally-sharded array from per-host staged
shards (``jax.make_array_from_single_device_arrays``).  Three constructors
cover the deployment spectrum:

* :meth:`ProcessMesh.from_runtime` — a genuinely multi-process jax runtime
  (``jax.distributed.initialize`` was called; ``jax.process_count() >= 1``).
* :meth:`ProcessMesh.virtual` — ONE process partitions its own devices into
  virtual "hosts" (tests / examples exercise the per-host staging and global
  assembly code paths without a pod).
* :meth:`ProcessMesh.emulated` — one process of an N-process fake-device
  harness (see ``tests/multihost.py``): jax only sees the local devices, the
  global topology is synthesized from ``(process_id, num_processes)``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where supported (jax >= 0.5);
    0.4.x has neither the kwarg nor jax.sharding.AxisType."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: ``jax.set_mesh``
    (jax >= 0.6) / ``jax.sharding.use_mesh`` (0.5.x) / the Mesh object's own
    context manager (0.4.x resource-env semantics)."""
    fn = getattr(jax, "set_mesh", None) or getattr(jax.sharding, "use_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-carrying axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def batch_sharding(mesh):
    """NamedSharding placing a batch dim across the mesh's data axes — the
    ``in_shardings`` a TransformPlan is lowered with on this mesh.
    Delegates to ``Engine`` so the two can never drift; use an Engine
    directly to shard over non-default data axes."""
    from repro.core.engine import Engine

    return Engine(mesh, data_axes=data_axes(mesh)).batch_sharding()


def mesh_fingerprint(mesh) -> Tuple:
    """Hashable identity of a mesh: axis names, per-axis sizes, device ids,
    and — when any device is remote — the per-device owning process.

    Two meshes with the same fingerprint produce equal NamedShardings and
    therefore hit the same entry in a TransformPlan's executable cache; a
    differing fingerprint is a guaranteed cache miss.  Process topology is
    part of the identity: the same device ids partitioned over a different
    number of hosts lower to different programs (different collectives), so
    they must not collide on one executable.  Single-process meshes keep the
    historical 3-tuple shape (all-zero process rows add no information and
    would churn every existing cache key)."""
    if mesh is None:
        return ()
    sizes = tuple(mesh.shape[a] for a in mesh.axis_names)
    devs = tuple(int(d.id) for d in mesh.devices.flat)
    procs = tuple(int(getattr(d, "process_index", 0)) for d in mesh.devices.flat)
    if any(p != 0 for p in procs):
        return (tuple(mesh.axis_names), sizes, devs, procs)
    return (tuple(mesh.axis_names), sizes, devs)


def sharding_fingerprint(sharding) -> Tuple:
    """Hashable identity of an (optional) sharding: the owning mesh's
    fingerprint plus the partition spec.  ``None`` (single default device)
    fingerprints to ``()``.  This is the cache key FusedModel lowers its
    fused executable under — two shardings with equal fingerprints place
    batches identically, so they may share one compiled program."""
    if sharding is None:
        return ()
    mesh = getattr(sharding, "mesh", None)
    spec = getattr(sharding, "spec", None)
    if mesh is None:  # e.g. SingleDeviceSharding / PositionalSharding
        # no mesh+spec to identify the layout, so fold in the repr: distinct
        # layouts over the same devices must NOT collide on one executable
        # (a collision silently serves the wrong placement; the worst a
        # too-fine key costs is a duplicate compile)
        devs = tuple(sorted(int(d.id) for d in getattr(sharding, "device_set", ())))
        return (type(sharding).__name__, devs, repr(sharding))
    return (mesh_fingerprint(mesh), str(spec))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return _make_mesh((data, model), ("data", "model"))


# ---------------------------------------------------------------------------
# Process-aware topology: which host feeds which rows of a global batch
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProcessMesh:
    """Process topology of a (possibly multi-host) device mesh.

    The contract every consumer relies on: the global batch dimension is
    sharded over ``num_data_shards`` equal(ish) row blocks in data-shard
    order, and shard ``i`` belongs to process ``shard_process[i]``.  Shards
    owned by one process are required to be CONTIGUOUS in that order, so a
    process's contribution to any global batch is one row slice —
    :meth:`row_block` — which is what the PlanRunner stages and what the
    gateway's coordinator routes.

    Fields:
      process_id / num_processes: this process's coordinate in the job.
      shard_process: owning process per global data shard (length =
        ``num_data_shards``), non-decreasing.
      local_mesh: mesh over THIS process's devices (execution happens here
        in ``local`` shard mode; in ``global`` mode it is the staging target
        for the addressable shards of the global array).
      global_mesh: the whole-job mesh, when this process can see it (real
        ``jax.distributed`` runtime, or single-process virtual topology).
        ``None`` in the emulated-subprocess harness, where jax only knows
        the local devices.
      data_axes: mesh axis name(s) carrying the batch dimension.
    """

    process_id: int
    num_processes: int
    shard_process: Tuple[int, ...]
    local_mesh: object
    global_mesh: Optional[object] = None
    data_axes: Tuple[str, ...] = ("data",)

    def __post_init__(self):
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(
                f"process_id {self.process_id} outside [0, {self.num_processes})"
            )
        if list(self.shard_process) != sorted(self.shard_process):
            # non-contiguous ownership would make a process's rows of a
            # global batch a gather, not a slice — nothing downstream
            # (pinned staging, zero-copy host views) supports that
            raise ValueError(
                f"per-process data shards must be contiguous, got {self.shard_process}"
            )
        if self.my_shards == (None, None):
            raise ValueError(
                f"process {self.process_id} owns no data shard of {self.shard_process}"
            )

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_runtime(cls, mesh=None, data_axes=("data",)) -> "ProcessMesh":
        """Topology of the live jax runtime (``jax.distributed``-style).

        ``mesh`` defaults to a 1-D ``("data",)`` mesh over every device of
        every process, in `jax.devices()` order.  Each data shard must be
        owned by exactly one process (model-axis groups never straddle
        hosts — true of TPU slices and of the fake-device harness)."""
        data_axes = (data_axes,) if isinstance(data_axes, str) else tuple(data_axes)
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), data_axes[:1])
        shard_process = _shard_process_map(mesh, data_axes)
        pid = int(jax.process_index())
        nproc = int(jax.process_count())
        local = [d for d in mesh.devices.flat if d.process_index == pid]
        local_mesh = _submesh(mesh, local, data_axes) if nproc > 1 else mesh
        return cls(pid, nproc, shard_process, local_mesh, mesh, data_axes)

    @classmethod
    def virtual(
        cls, mesh, num_processes: int, process_id: int = 0, data_axes=("data",)
    ) -> "ProcessMesh":
        """One process plays host ``process_id`` of ``num_processes`` over a
        mesh it fully owns — the data shards are partitioned into contiguous
        per-"host" blocks.  Because every device is addressable, the GLOBAL
        staging path (``make_array_from_single_device_arrays``) genuinely
        runs, which is how the single-process tests exercise it."""
        data_axes = (data_axes,) if isinstance(data_axes, str) else tuple(data_axes)
        n_shards = len(_shard_process_map(mesh, data_axes))
        if n_shards % num_processes:
            raise ValueError(
                f"{n_shards} data shards do not partition over {num_processes} processes"
            )
        per = n_shards // num_processes
        shard_process = tuple(i // per for i in range(n_shards))
        local = _shard_devices(mesh, data_axes, process_id, shard_process)
        local_mesh = _submesh(mesh, local, data_axes)
        return cls(process_id, num_processes, shard_process, local_mesh, mesh, data_axes)

    @classmethod
    def emulated(
        cls, num_processes: int, process_id: int, local_mesh=None, data_axes=("data",)
    ) -> "ProcessMesh":
        """One process of an N-process fake-device harness: jax sees only
        the local devices; the global topology (every process shaped like
        this one) is synthesized.  ``local_mesh`` defaults to a 1-D
        ``("data",)`` mesh over the local devices."""
        data_axes = (data_axes,) if isinstance(data_axes, str) else tuple(data_axes)
        if local_mesh is None:
            local_mesh = jax.make_mesh((len(jax.devices()),), data_axes[:1])
        local_shards = len(_shard_process_map(local_mesh, data_axes))
        shard_process = tuple(
            p for p in range(num_processes) for _ in range(local_shards)
        )
        return cls(process_id, num_processes, shard_process, local_mesh, None, data_axes)

    def degraded(self, dead) -> "ProcessMesh":
        """Topology with the data shards of ``dead`` processes reassigned to
        survivors — the mesh the serving coordinator reshards over when a
        worker dies.

        Each orphan shard goes to the owner of the nearest PRECEDING live
        shard, which keeps ``shard_process`` non-decreasing (the contiguity
        contract every consumer relies on); when no live process precedes,
        the first live owner absorbs — in the gateway topology process 0 is
        the coordinator and always live, so the coordinator absorbs orphan
        rows as the fallback.  Process ids keep their original numbering: a
        degraded mesh is the SAME job minus capacity, so routing tables and
        per-process telemetry stay keyed consistently, and a rejoining
        worker simply reverts to the undegraded topology."""
        dead = frozenset(int(d) for d in dead)
        if not dead:
            return self
        if self.process_id in dead:
            raise ValueError(
                f"process {self.process_id} cannot derive a mesh degraded by "
                "its own death"
            )
        live = [p for p in self.shard_process if p not in dead]
        if not live:
            raise ValueError(f"no live process left in {self.shard_process}")
        new = []
        last_live: Optional[int] = None
        for p in self.shard_process:
            if p not in dead:
                last_live = p
            new.append(last_live if last_live is not None else live[0])
        return dataclasses.replace(self, shard_process=tuple(new))

    # -- shard / row arithmetic -------------------------------------------

    @property
    def num_data_shards(self) -> int:
        return len(self.shard_process)

    @property
    def my_shards(self) -> Tuple[Optional[int], Optional[int]]:
        """(first, one-past-last) global data shard owned by this process."""
        mine = [i for i, p in enumerate(self.shard_process) if p == self.process_id]
        if not mine:
            return (None, None)
        return (mine[0], mine[-1] + 1)

    def shard_row_blocks(self, n_rows: int) -> List[Tuple[int, int]]:
        """Row range of every global data shard for an ``n_rows`` batch.

        Uneven row counts follow ``np.array_split`` (leading shards one row
        longer) — the layout jax itself uses for uneven shardings, and the
        one the local execution mode can always honour."""
        base, extra = divmod(n_rows, self.num_data_shards)
        blocks, start = [], 0
        for i in range(self.num_data_shards):
            stop = start + base + (1 if i < extra else 0)
            blocks.append((start, stop))
            start = stop
        return blocks

    def row_block(self, n_rows: int) -> Tuple[int, int]:
        """The contiguous row slice of an ``n_rows`` global batch THIS
        process feeds (and, in local shard mode, computes)."""
        blocks = self.shard_row_blocks(n_rows)
        lo, hi = self.my_shards
        return (blocks[lo][0], blocks[hi - 1][1])

    @property
    def addressable_shards(self) -> Tuple[int, int]:
        """(first, one-past-last) data shard whose devices the CURRENT jax
        process can stage onto.  Equal to :attr:`my_shards` on a real
        multi-process runtime and in the emulated harness; in virtual
        topologies one process owns every device, so global assembly must
        cover all shards (jax requires every addressable shard)."""
        if self.global_mesh is None:
            return self.my_shards
        pid = int(jax.process_index())
        mine = [
            i
            for i in range(self.num_data_shards)
            if all(
                int(getattr(d, "process_index", 0)) == pid
                for d in _shard_devices(self.global_mesh, self.data_axes, i)
            )
        ]
        if not mine:
            raise ValueError("no addressable data shards on this process")
        return (mine[0], mine[-1] + 1)

    def addressable_row_block(self, n_rows: int) -> Tuple[int, int]:
        """Rows of an ``n_rows`` global batch this jax process must place
        on device for global assembly (see :attr:`addressable_shards`)."""
        blocks = self.shard_row_blocks(n_rows)
        lo, hi = self.addressable_shards
        return (blocks[lo][0], blocks[hi - 1][1])

    # -- fingerprints ------------------------------------------------------

    def fingerprint(self) -> Tuple:
        """Job-wide identity: same on every process of one job (the compiled
        program is SPMD), different across topologies."""
        return (
            mesh_fingerprint(self.global_mesh),
            self.num_processes,
            self.shard_process,
            self.data_axes,
        )

    def local_fingerprint(self) -> Tuple:
        """Per-process identity: the job fingerprint plus which host this is
        and what it executes on (local executable caches key on this)."""
        return self.fingerprint() + (self.process_id, mesh_fingerprint(self.local_mesh))

    # -- staging -----------------------------------------------------------

    def local_batch_sharding(self):
        """Row sharding of this process's block over the local mesh."""
        from jax.sharding import NamedSharding, PartitionSpec

        axes = tuple(a for a in self.data_axes if a in self.local_mesh.axis_names)
        return NamedSharding(self.local_mesh, PartitionSpec(axes or None))

    def global_batch_sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec

        if self.global_mesh is None:
            raise ValueError(
                "no global mesh: emulated topologies execute in 'local' shard "
                "mode (the harness reassembles host-side)"
            )
        return NamedSharding(self.global_mesh, PartitionSpec(self.data_axes))

    def stage_global(self, local_block: dict, n_rows: int) -> dict:
        """Assemble globally-sharded arrays from this process's row block.

        ``local_block`` holds host columns covering exactly
        ``addressable_row_block(n_rows)``; each addressable data shard's rows
        are placed
        on its devices and the global array is assembled with
        ``jax.make_array_from_single_device_arrays`` — every process calls
        this with only ITS rows, which is the whole point: no host ever
        materialises the global batch.  Requires ``n_rows`` to divide evenly
        over the data shards (jax's constraint on assembled arrays)."""
        sharding = self.global_batch_sharding()
        blocks = self.shard_row_blocks(n_rows)
        if len({b[1] - b[0] for b in blocks}) != 1:
            raise ValueError(
                f"global staging needs {n_rows} rows to divide over "
                f"{self.num_data_shards} shards"
            )
        lo, hi = self.addressable_shards
        start = blocks[lo][0]
        out = {}
        for k, col in local_block.items():
            shards = []
            for i in range(lo, hi):
                b0, b1 = blocks[i]
                rows = col[b0 - start : b1 - start]
                for d in _shard_devices(self.global_mesh, self.data_axes, i):
                    shards.append(jax.device_put(rows, d))
            out[k] = jax.make_array_from_single_device_arrays(
                (n_rows,) + tuple(np.shape(col))[1:], sharding, shards
            )
        return out

    def __repr__(self) -> str:
        kind = (
            "emulated"
            if self.global_mesh is None
            else ("virtual" if self.num_processes > 1 and jax.process_count() == 1 else "runtime")
        )
        return (
            f"ProcessMesh({kind}, process {self.process_id}/{self.num_processes}, "
            f"shards={self.my_shards} of {self.num_data_shards})"
        )


def _data_coords(mesh, data_axes) -> List[Tuple[int, ...]]:
    """Data-shard coordinates of ``mesh`` in row-major (shard-index) order."""
    sizes = [mesh.shape[a] for a in data_axes if a in mesh.axis_names]
    return [tuple(c) for c in np.ndindex(*sizes)] if sizes else [()]


def _shard_devices(mesh, data_axes, shard: int, shard_process=None) -> List:
    """Devices holding global data shard ``shard`` (its model-axis group).
    With ``shard_process`` given, instead returns every device of process
    ``shard`` (the virtual-topology constructor's grouping)."""
    axis_pos = {a: i for i, a in enumerate(mesh.axis_names)}
    data_pos = [axis_pos[a] for a in data_axes if a in axis_pos]
    coords = _data_coords(mesh, data_axes)
    devs = []
    for idx in np.ndindex(*mesh.devices.shape):
        c = tuple(idx[p] for p in data_pos)
        i = coords.index(c)
        if shard_process is not None:
            if shard_process[i] == shard:
                devs.append(mesh.devices[idx])
        elif i == shard:
            devs.append(mesh.devices[idx])
    return devs


def _shard_process_map(mesh, data_axes) -> Tuple[int, ...]:
    """Owning process per data shard; raises if a shard straddles hosts."""
    procs = []
    for shard in range(len(_data_coords(mesh, data_axes))):
        owners = {
            int(getattr(d, "process_index", 0))
            for d in _shard_devices(mesh, data_axes, shard)
        }
        if len(owners) != 1:
            raise ValueError(
                f"data shard {shard} straddles processes {sorted(owners)}: "
                "model-axis groups must live on one host"
            )
        procs.append(owners.pop())
    return tuple(procs)


def _submesh(mesh, devices, data_axes):
    """Mesh over one process's devices, same axis names: data axes collapse
    into the FIRST data axis (local shard count), model axes keep their
    sizes.  Shardings written against the global axis names keep working."""
    from jax.sharding import Mesh

    axis_pos = {a: i for i, a in enumerate(mesh.axis_names)}
    model_axes = [a for a in mesh.axis_names if a not in data_axes]
    model_sizes = [mesh.shape[a] for a in model_axes]
    n_local = len(devices)
    model_total = int(np.prod(model_sizes)) if model_sizes else 1
    shape = []
    first_data = True
    for a in mesh.axis_names:
        if a in data_axes:
            shape.append(n_local // model_total if first_data else 1)
            first_data = False
        else:
            shape.append(mesh.shape[a])
    # devices arrive in mesh-iteration order (data-major); reshape directly
    arr = np.array(devices, dtype=object).reshape(tuple(shape))
    return Mesh(arr, mesh.axis_names)
