"""Serving CLI: load a preprocessing bundle + backbone, serve batched
requests through the MicroBatcher (the paper's production deployment shape).

    PYTHONPATH=src python -m repro.launch.serve --requests 200
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.apps.ltr_pipeline import build_ltr_pipeline
from repro.data import ltr_rows
from repro.serve import FusedModel
from repro.serve.batcher import MicroBatcher


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    args = ap.parse_args(argv)

    train = ltr_rows(512, seed=0)
    fitted, feats = build_ltr_pipeline(train)
    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.normal(0, 0.1, (len(feats), 64)), jnp.float32),
        "w2": jnp.asarray(rng.normal(0, 0.1, (64, 1)), jnp.float32),
    }

    def head(params, f):
        import jax

        x = jnp.stack([f[c].astype(jnp.float32) for c in feats], axis=-1)
        h = jax.nn.relu(jnp.einsum("qlf,fh->qlh", x, params["w1"]))
        return jnp.einsum("qlh,ho->qlo", h, params["w2"])[..., 0]

    fm = FusedModel(fitted.export(outputs=feats), head, params)
    batcher = MicroBatcher(fm, max_batch=args.max_batch, max_wait_ms=args.max_wait_ms)

    pool = ltr_rows(max(args.requests, 2), seed=3)
    pool.pop("label_click")
    lat = []
    t0 = time.perf_counter()
    import concurrent.futures as cf

    def one(i):
        req = {k: np.asarray(v[i]) for k, v in pool.items()}
        t = time.perf_counter()
        out = batcher.submit(req)
        lat.append(time.perf_counter() - t)
        return out

    with cf.ThreadPoolExecutor(max_workers=16) as ex:
        list(ex.map(one, range(args.requests)))
    dt = time.perf_counter() - t0
    lat.sort()
    print(
        f"[serve] {args.requests} req in {dt:.2f}s ({args.requests/dt:.0f} rps) "
        f"p50={lat[len(lat)//2]*1e3:.1f}ms p99={lat[int(len(lat)*0.99)]*1e3:.1f}ms "
        f"batches={batcher.batches_run} avg_batch={batcher.rows_served/max(batcher.batches_run,1):.1f}"
    )
    batcher.close()


if __name__ == "__main__":
    main()
