"""Static analysis of compiled HLO text: loop-aware FLOP and collective-byte
accounting.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so models driven by
``lax.scan`` over layers (everything here — that is what keeps 62-layer
compiles tractable) would be undercounted by ~n_layers.  XLA annotates loops
with ``known_trip_count``, so we recover exact totals by walking the call
graph:

    total(comp) = local(comp) + sum_child multiplier(child) * total(child)

where multiplier is the trip count for while bodies (1 for conditions,
fusions, calls; conditionals take the max across branches).

local FLOPs = 2 * prod(result_dims) * prod(contracting_dims) per ``dot``
(matmul-dominated models; elementwise FLOPs are deliberately excluded and the
omission is documented in EXPERIMENTS.md).  Collective bytes = result-shape
bytes per all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_TOK = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred|c64|c128)\[([\d,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)([^,)}]+(?:,\s*%[\w\.\-]+)*)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_TOK.search(text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.coll_bytes: Dict[str, float] = {}
        self.coll_count: Dict[str, float] = {}
        # (child_name, multiplier, is_branch)
        self.children: List[Tuple[str, float]] = []


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    shapes: Dict[str, str] = {}
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.strip().endswith("{"):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry_name = m.group(1)
                shapes = {}
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # record result shape text (up to the opcode) for operand lookup
        shapes[name] = rhs.split(" ", 1)[0] if "[" in rhs.split(" ", 1)[0] else rhs
        # --- dot flops -------------------------------------------------
        dm = re.search(r"\bdot\(%?([\w\.\-]+)", rhs)
        if dm:
            res_dims = _shape_dims(rhs.split("dot(")[0])
            lhs_name = dm.group(1)
            lhs_text = shapes.get(lhs_name, "")
            lhs_dims = _shape_dims(lhs_text)
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            k = 1
            if cdims and lhs_dims:
                for ci in cdims.group(1).split(","):
                    if ci:
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
            n_res = 1
            for d in res_dims:
                n_res *= d
            cur.flops += 2.0 * n_res * k
        # --- collectives ----------------------------------------------
        for kind in COLLECTIVES:
            if re.search(rf"\b{kind}(?:-start)?\(", rhs):
                pre = rhs.split(kind)[0]
                cur.coll_bytes[kind] = cur.coll_bytes.get(kind, 0.0) + _shape_bytes(pre)
                cur.coll_count[kind] = cur.coll_count.get(kind, 0.0) + 1
                break
        # --- children ---------------------------------------------------
        if "while(" in rhs:
            tm = _TRIP.search(rhs)
            trip = float(tm.group(1)) if tm else 1.0
            bm = re.search(r"body=%?([\w\.\-]+)", rhs)
            cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
            if bm:
                cur.children.append((bm.group(1), trip))
            if cm:
                cur.children.append((cm.group(1), trip))
        else:
            for attr in ("calls", "to_apply"):
                am = re.search(rf"{attr}=%?([\w\.\-]+)", rhs)
                if am:
                    cur.children.append((am.group(1), 1.0))
            bm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if bm:
                for b in bm.group(1).split(","):
                    cur.children.append((b.strip().lstrip("%"), 1.0))
    comps["__entry__"] = comps.get(entry_name, Computation("__missing__"))
    comps["__entry_name__"] = entry_name  # type: ignore
    return comps


def analyse_hlo(hlo: str):
    comps = parse_module(hlo)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    memo: Dict[str, Tuple[float, Dict[str, float], Dict[str, float]]] = {}

    def total(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, {}, {}
        c = comps[name]
        f = c.flops
        cb = dict(c.coll_bytes)
        cc = dict(c.coll_count)
        for child, mult in c.children:
            cf, ccb, ccc = total(child, stack + (name,))
            f += mult * cf
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0.0) + mult * v
            for k, v in ccc.items():
                cc[k] = cc.get(k, 0.0) + mult * v
        memo[name] = (f, cb, cc)
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "coll_bytes": {}, "coll_count": {}}
    f, cb, cc = total(entry)
    return {"flops": f, "coll_bytes": cb, "coll_count": cc}
