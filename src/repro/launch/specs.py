"""Input/state specs per (architecture x input shape) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input — shardable, zero allocation — exactly what
``jax.jit(...).lower()`` needs for the dry-run.  Shapes follow the assignment
table:

    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (serve prefill forward)
    decode_32k   one token,  KV ctx 32768, global_batch 128 (serve_step)
    long_500k    one token,  ctx 524288, global_batch 1     (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# full-attention archs skip long_500k (O(n^2) at 524k is not deployable);
# see DESIGN.md §Arch-applicability.
LONG_CONTEXT_FAMILIES = ("rglru", "mamba2")


def cell_is_applicable(cfg, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, "full quadratic attention at 524k context — documented skip"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for the model inputs of one cell."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    if kind == "train":
        specs = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        if cfg.family == "whisper":
            specs["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model), jnp.float32)
        return specs
    if kind == "prefill":
        specs = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "whisper":
            specs["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            specs["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model), jnp.float32)
        return specs
    # decode: one new token against a primed cache of S tokens
    return {"tokens": _sds((B, 1), jnp.int32)}


def batch_pspec(mesh) -> P:
    from .mesh import data_axes

    return P(data_axes(mesh))


def input_pspecs(cfg, shape_name: str, mesh) -> Dict[str, P]:
    b = batch_pspec(mesh)
    specs = input_specs(cfg, shape_name)
    out = {}
    for k, v in specs.items():
        out[k] = P(b[0], *([None] * (len(v.shape) - 1)))
    return out


def cache_abstract(model, cfg, shape_name: str):
    sh = SHAPES[shape_name]
    return jax.eval_shape(lambda: model.init_cache(sh["batch"], sh["seq"]))


def cache_pspecs(cache_tree, mesh) -> Any:
    """PartitionSpecs for a (layer-stacked) decode cache, by leaf name/rank.

    batch axis -> data axes; head/state/feature axes -> "model"."""
    from .mesh import data_axes

    b = data_axes(mesh)

    def spec(path, leaf):
        key = None
        for entry in reversed(path):
            k = getattr(entry, "key", None)
            if isinstance(k, str):
                key = k
                break
        nd = len(leaf.shape)
        in_cross = any(getattr(e, "key", None) == "cross" for e in path)
        if key == "pos" or nd <= 1:
            return P()
        if key in ("k", "v"):
            if in_cross:  # (L, B, T_enc, H, hd): enc_seq rarely divides -> heads
                return P(None, b, None, "model", None)
            # self KV (L, B, W, KV, hd).  Prefer HEAD sharding when the KV
            # head count fills the TP axis: the rolling/append
            # dynamic-update-slice then stays shard-local (§Perf change #3 —
            # a dynamic index on a sharded dim forces GSPMD full
            # rematerialisation).  Otherwise SEQUENCE-shard (flash-decode
            # style): softmax/contract over the sharded axis reduce to tiny
            # per-head all-reduces, at the cost of the DUS gather.
            tp = mesh.shape.get("model", 1)
            if len(leaf.shape) == 5 and leaf.shape[3] % tp == 0:
                return P(None, b, None, "model", None)
            return P(None, b, "model", None, None)
        if key == "c":  # MLA latent (L, B, S, kr): seq-sharded
            return P(None, b, "model", None)
        if key == "k_rope":  # (L, B, S, dr): seq-sharded
            return P(None, b, "model", None)
        if key == "state":  # mamba (L, B, H, N, P)
            return P(None, b, "model", None, None)
        if key == "conv":  # (L, B, K, C)
            return P(None, b, None, "model")
        if key == "h":  # rg-lru (L, B, R)
            return P(None, b, "model")
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)
