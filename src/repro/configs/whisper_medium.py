"""Whisper-medium [arXiv:2212.04356] — enc-dec; conv/mel frontend is a STUB
(input_specs feeds (B, 1500, 1024) frame embeddings).  max_target_len is
sized so the decode_32k stress shape has a positional table to index."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="whisper",
    n_layers=24,  # decoder layers
    enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    qkv_bias=True,
    norm="ln",
    max_target_len=32768,
    remat="full",
)
