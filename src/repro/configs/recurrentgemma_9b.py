"""RecurrentGemma-9B [arXiv:2402.19427] — Griffin: RG-LRU + local attention,
pattern (rec, rec, attn) 1:2, window 2048, MQA kv=1 head_dim=256."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="rglru",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    norm="rms",
    lru_width=4096,
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    remat="full",
)
