"""StableLM (stabilityai family) — dense, LayerNorm, partial rotary 25%."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab=50304,
    qkv_bias=False,
    rope_theta=10000.0,
    rotary_pct=0.25,
    norm="ln",
    remat="full",
)
