"""DeepSeek-V2-236B [arXiv:2405.04434] — MLA (kv_lora=512) + fine-grained MoE
(2 shared + 160 routed, top-6), first layer dense."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="mla_moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # informational; MLA replaces per-head KV
    head_dim=128,
    d_ff=1536,  # routed expert width
    vocab=102400,
    norm="rms",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_routed_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    first_dense_ff=12288,
    remat="full",
)
