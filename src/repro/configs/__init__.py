"""Assigned architecture configs (exact published shapes) + paper use-cases.

Every config module exposes CONFIG (the full published architecture) built on
:class:`repro.configs.base.ArchConfig`; ``get(name)`` resolves by id.
"""
from importlib import import_module

ARCH_IDS = [
    "codeqwen1_5_7b",
    "stablelm_3b",
    "deepseek_coder_33b",
    "qwen2_5_32b",
    "pixtral_12b",
    "deepseek_moe_16b",
    "deepseek_v2_236b",
    "whisper_medium",
    "recurrentgemma_9b",
    "mamba2_780m",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIAS.update({
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen2.5-32b": "qwen2_5_32b",
})


def get(name: str):
    mod = _ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    return import_module(f"repro.configs.{mod}").CONFIG
