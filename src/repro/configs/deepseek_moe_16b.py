"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained MoE: 2 shared + 64
routed experts, top-6, first layer dense."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # routed expert width
    vocab=102400,
    norm="rms",
    n_routed_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    first_dense_ff=10944,
    remat="full",
)
