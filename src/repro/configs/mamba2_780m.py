"""Mamba2-780m [arXiv:2405.21060] — attention-free SSD, state=128."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="mamba2",
    n_layers=48,
    d_model=1536,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    norm="rms",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=128,
    tie_embed=True,
    remat="full",
)
