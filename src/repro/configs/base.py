"""ArchConfig: one declarative schema covering all ten assigned architecture
families, plus execution policy (dtype, remat, scan, pallas).  Each file in
this package instantiates the EXACT published config and a reduced smoke
config of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass
class ArchConfig:
    name: str = "arch"
    family: str = "dense"  # dense | moe | mla_moe | whisper | vlm | rglru | mamba2
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0  # partial rotary (stablelm-2 uses 0.25)
    norm: str = "rms"  # rms | ln
    tie_embed: bool = False

    # --- MoE ---------------------------------------------------------------
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading layers with dense FFN (DeepSeek)
    first_dense_ff: int = 0
    moe_aux_coef: float = 0.001
    moe_capacity_factor: float = 1.25
    moe_norm_top_k: bool = True

    # --- MLA (DeepSeek-V2) --------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- RG-LRU hybrid (RecurrentGemma) --------------------------------------
    lru_width: int = 0
    window: Optional[int] = None  # local attention window
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")

    # --- Mamba2 / SSD ---------------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 64
    conv_width: int = 4

    # --- modality stubs -------------------------------------------------------
    num_patches: int = 0  # vlm: stub patch embeddings prepended to text
    enc_layers: int = 0  # whisper encoder depth
    enc_seq: int = 1500  # whisper: fixed frame count (stub conv frontend)

    # --- execution policy -------------------------------------------------------
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    use_pallas: bool = False
    remat: str = "none"  # none | dots | full
    scan_layers: bool = True
    max_target_len: int = 448  # whisper decoder positional table size floor

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def smoke(self) -> "ArchConfig":
        """A reduced same-family config for CPU smoke tests."""
        small = dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if not self.block_pattern else len(self.block_pattern) + 1),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            vocab=512,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=32 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            n_routed_experts=8 if self.n_routed_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            first_dense_ff=128 if self.first_dense_ff else 0,
            lru_width=128 if self.lru_width else 0,
            window=min(self.window, 64) if self.window else None,
            ssm_state=32 if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 64,
            num_patches=16 if self.num_patches else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=64 if self.enc_layers else 1500,
            compute_dtype=jnp.float32,
            remat="none",
        )
        return small
