"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — mistral-nemo decoder backbone
with a STUB ViT frontend (input_specs feeds precomputed patch embeddings;
see DESIGN.md §Arch-applicability)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    norm="rms",
    num_patches=256,  # stub patch-embedding sequence prepended to text
    remat="full",
)
