"""Distributed fit/transform engine — the "Spark" role of the paper, played
by a JAX device mesh.

Batches are sharded over the ``data`` axis; estimator statistics are
replicated outputs, so XLA inserts the cross-shard reductions (all-reduce of
moment sums, gather+merge of vocab tables) exactly where Spark would run
treeAggregate.  One code path covers 1 CPU device (tests), one pod, and the
multi-pod production mesh (where the reduction becomes hierarchical:
intra-pod ICI then inter-pod DCI).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Engine:
    """Execution context for pipeline fit/transform.

    Args:
      mesh: device mesh; None = single default device.
      data_axes: mesh axis name(s) carrying the batch dimension.  On the
        production mesh this is ("pod", "data") so batches shard across pods
        AND across data-parallel groups within a pod.
    """

    def __init__(self, mesh: Optional[Mesh] = None, data_axes=("data",)):
        self.mesh = mesh
        self.data_axes = tuple(data_axes) if not isinstance(data_axes, str) else (data_axes,)

    # -- sharding helpers -------------------------------------------------
    def batch_spec(self) -> P:
        return P(self.data_axes)

    def batch_sharding(self):
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.batch_spec())

    def shard_batch(self, batch):
        """Place a host batch onto the mesh, sharded along the batch dim."""
        if self.mesh is None:
            return batch
        sh = self.batch_sharding()
        return {k: jax.device_put(v, sh) for k, v in batch.items()}

    def data_shard_count(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    # -- jit wrappers ------------------------------------------------------
    def jit_fit_step(self, fn: Callable):
        """stats, batch -> stats with batch sharded and stats replicated."""
        if self.mesh is None:
            return jax.jit(fn)
        repl = NamedSharding(self.mesh, P())
        batch_sh = self.batch_sharding()

        def spec_for(stats, batch):
            stats_sh = jax.tree.map(lambda _: repl, stats)
            batch_shs = jax.tree.map(lambda _: batch_sh, batch)
            return stats_sh, batch_shs

        jitted = {}

        def wrapper(stats, batch):
            key = tuple(sorted(batch.keys()))
            if key not in jitted:
                in_sh = spec_for(stats, batch)
                jitted[key] = jax.jit(
                    fn,
                    in_shardings=in_sh,
                    out_shardings=jax.tree.map(lambda _: repl, stats),
                )
            return jitted[key](stats, batch)

        return wrapper

    def jit_transform(self, fn: Callable):
        """batch -> batch, sharded in and out along the data axes.

        A :class:`~repro.core.plan.TransformPlan` delegates to the plan's own
        sharding-aware executable cache (keyed on signature + shardings +
        donation), so the SAME plan instance serves this engine and any other
        execution context without re-analysis.  For a plain callable, the
        wrapper cache is keyed on the full input signature — names, shapes
        AND dtypes — so a batch-size change compiles a new entry instead of
        silently re-tracing an existing one."""
        if hasattr(fn, "jit_for"):  # TransformPlan (or compatible)
            return fn.jit_for(engine=self)
        if self.mesh is None:
            return jax.jit(fn)
        batch_sh = self.batch_sharding()
        jitted = {}

        def wrapper(batch):
            key = tuple(
                (k, tuple(v.shape), str(v.dtype)) for k, v in sorted(batch.items())
            )
            if key not in jitted:
                jitted[key] = jax.jit(
                    fn,
                    in_shardings=jax.tree.map(lambda _: batch_sh, batch),
                    out_shardings=None,  # let XLA propagate; outputs stay sharded
                )
            return jitted[key](batch)

        return wrapper
