"""64-bit string hashing primitives (TPU-native, pure integer ops).

The paper's hash / bloom indexing maps high-cardinality categoricals to
integer bins.  On TPU there is no string type, so we hash the uint8 byte
tensor directly with seeded FNV-1a-64 followed by a Murmur3-style avalanche
finalizer.  Trailing zero padding is masked out of the hash so the result is
independent of the configured ``max_len``.

This is the reference (pure-jnp) implementation; ``repro.kernels.bloom_hash``
provides the Pallas hot-path with identical semantics, and the kernel tests
assert bit-exactness against these functions.
"""
from __future__ import annotations

import jax

from repro.obs import envknobs

from . import types as _types  # noqa: F401  (enables x64 before uint64 constants)

import jax.numpy as jnp  # noqa: E402

FNV_OFFSET = jnp.uint64(14695981039346656037)
FNV_PRIME = jnp.uint64(1099511628211)


def _avalanche(h: jax.Array) -> jax.Array:
    """Murmur3 fmix64: improves low-bit diffusion of FNV for modulo binning."""
    h = h ^ (h >> jnp.uint64(33))
    h = h * jnp.uint64(0xFF51AFD7ED558CCD)
    h = h ^ (h >> jnp.uint64(33))
    h = h * jnp.uint64(0xC4CEB9FE1A85EC53)
    h = h ^ (h >> jnp.uint64(33))
    return h


def fnv1a64(strings: jax.Array, seed: int = 0) -> jax.Array:
    """Seeded FNV-1a-64 over the trailing byte axis of a string tensor.

    Args:
      strings: uint8 array ``(..., max_len)``, zero padded.
      seed: integer seed (bloom indexing uses seeds 0..k-1).

    Returns:
      uint64 array ``(...,)``.  Padding bytes (0) do not update the state, so
      hashes are max_len-invariant.

    Implementation: a ``lax.scan`` over the byte axis.  The per-step ops are
    identical to the historical unrolled loop (so results are bit-exact), but
    the traced/lowered program is O(1) in ``max_len`` instead of O(max_len) —
    this dominates whole-pipeline trace time once dozens of stages hash
    32-to-64-byte columns.
    """
    s = jnp.moveaxis(strings, -1, 0).astype(jnp.uint64)  # (L, ...)
    h0 = jnp.full(strings.shape[:-1], FNV_OFFSET ^ jnp.uint64(seed), jnp.uint64)

    def step(h, b):
        return jnp.where(b == 0, h, (h ^ b) * FNV_PRIME), None

    h, _ = jax.lax.scan(step, h0, s)
    return _avalanche(h)


def fold32(h: jax.Array) -> jax.Array:
    """Fold a 64-bit hash to 32 bits (hi ^ lo) — the TPU-native binning form
    (TPU vector units have no 64-bit modulo; the Pallas kernel computes the
    same fold from its 32-bit limbs, keeping kernel/jnp parity bit-exact)."""
    return (h ^ (h >> jnp.uint64(32))).astype(jnp.uint32)


def hash_to_bins(strings: jax.Array, num_bins: int, seed: int = 0) -> jax.Array:
    """Hash strings into ``[0, num_bins)`` (the paper's HashIndexTransformer)."""
    return (fold32(fnv1a64(strings, seed)) % jnp.uint32(num_bins)).astype(jnp.int64)


def bloom_indices(strings: jax.Array, num_bins: int, num_hashes: int) -> jax.Array:
    """Bloom encoding [Serrà & Karatzoglou 2017]: ``num_hashes`` independent
    hash-bin indices per string, stacked on a new trailing axis.

    Returns int64 ``(..., num_hashes)``.
    """
    outs = [hash_to_bins(strings, num_bins, seed=k) for k in range(num_hashes)]
    return jnp.stack(outs, axis=-1)


def hash_int64(values: jax.Array, seed: int = 0) -> jax.Array:
    """Hash an integer column (splitmix-style) — used when inputDtype is not
    string but hash indexing is requested on raw ids."""
    h = values.astype(jnp.uint64) + jnp.uint64(0x9E3779B97F4A7C15) * jnp.uint64(seed + 1)
    return _avalanche(h)


def int_to_bins(values: jax.Array, num_bins: int, seed: int = 0) -> jax.Array:
    return (fold32(hash_int64(values, seed)) % jnp.uint32(num_bins)).astype(jnp.int64)


# ---------------------------------------------------------------------------
# Pallas routing: on TPU the batch hashing hot path runs the bloom_hash
# kernel (bit-exact 32-bit-limb FNV); everywhere else the jnp scan above.
# REPRO_HASH_KERNEL=1 forces the kernel (interpret mode off-TPU, for tests);
# =0 forces the jnp path even on TPU.
# ---------------------------------------------------------------------------

def kernel_active() -> bool:
    flag = envknobs.env_tristate("REPRO_HASH_KERNEL")
    if flag is not None:
        return flag
    return jax.default_backend() == "tpu"


def fnv1a64_routed(strings: jax.Array, seed: int = 0) -> jax.Array:
    """fnv1a64, routed through the Pallas kernel when it is the fast path.

    The kernel carries the hash as two uint32 limbs (seed folded into the low
    limb), so only seeds < 2**32 are kernel-eligible; larger seeds fall back.
    """
    if kernel_active() and 0 <= seed < 2**32:
        from repro.kernels.bloom_hash import ops as khash

        return khash.fnv1a64_raw(strings, seed)
    return fnv1a64(strings, seed)


def hash_to_bins_routed(strings: jax.Array, num_bins: int, seed: int = 0) -> jax.Array:
    if kernel_active() and 0 <= seed < 2**32:
        from repro.kernels.bloom_hash import ops as khash

        return khash.hash_indices_seeded(strings, num_bins, seed)
    return hash_to_bins(strings, num_bins, seed)


def bloom_indices_routed(strings: jax.Array, num_bins: int, num_hashes: int) -> jax.Array:
    if kernel_active():
        from repro.kernels.bloom_hash import ops as khash

        return khash.bloom_indices(strings, num_bins, num_hashes)
    return bloom_indices(strings, num_bins, num_hashes)
