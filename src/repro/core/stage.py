"""Stage base classes: Transformer / Estimator / fitted stages.

Mirrors the paper's Spark pipeline API surface (inputCol / outputCol /
inputDtype / layerName, ``camelCase`` kept deliberately so Listing-1-style
code ports verbatim), while the execution semantics are JAX:

  * every stage owns ONE pure function ``apply(weights, inputs) -> outputs``;
  * the distributed fit/transform engine and the exported inference graph call
    the SAME function — offline/online parity holds by construction and is
    additionally asserted by tests;
  * estimators expose an associative, jit-able statistics monoid
    (``init_stats / update_stats / merge_stats``) so fitting streams over
    sharded batches and merges across data-parallel shards with one psum-like
    reduction, exactly as Spark's treeAggregate does.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import types as T

# Registry: op_name -> stage class (used by export/serialisation).
STAGE_REGISTRY: Dict[str, type] = {}


def register_stage(cls):
    STAGE_REGISTRY[cls.__name__] = cls
    return cls


@dataclasses.dataclass
class Stage:
    """Base for all pipeline stages.

    Exactly one of (inputCol, inputCols) must be set; same for outputs.
    ``inputDtype`` optionally casts inputs before the op (the paper uses this
    to e.g. force integer ids to strings before hashing).
    """

    inputCol: Optional[str] = None
    inputCols: Optional[Sequence[str]] = None
    outputCol: Optional[str] = None
    outputCols: Optional[Sequence[str]] = None
    inputDtype: Optional[str] = None
    outputDtype: Optional[str] = None
    layerName: Optional[str] = None
    # byte width used when inputDtype/internal ops must materialise strings
    maxLen: int = T.DEFAULT_MAX_LEN

    # ---- column plumbing -------------------------------------------------
    @property
    def input_names(self) -> List[str]:
        if self.inputCol is not None:
            return [self.inputCol]
        if self.inputCols is not None:
            return list(self.inputCols)
        return []

    @property
    def output_names(self) -> List[str]:
        if self.outputCol is not None:
            return [self.outputCol]
        if self.outputCols is not None:
            return list(self.outputCols)
        return []

    @property
    def name(self) -> str:
        return self.layerName or f"{type(self).__name__.lower()}_{id(self):x}"

    def __post_init__(self):
        if self.inputCol is not None and self.inputCols is not None:
            raise ValueError(f"{self.name}: set inputCol OR inputCols, not both")
        if self.outputCol is not None and self.outputCols is not None:
            raise ValueError(f"{self.name}: set outputCol OR outputCols, not both")

    # ---- dtype coercion ---------------------------------------------------
    def _coerce(self, x: jax.Array) -> jax.Array:
        d = self.inputDtype
        if d is None:
            return x
        if d == "string":
            if T.is_string_col(x):
                return x
            from . import strops

            return strops.number_to_string(x, self.maxLen)
        if T.is_string_col(x):
            from . import strops

            return strops.string_to_number(x, d)
        return x.astype(jnp.dtype(d))

    def _coerce_out(self, y: jax.Array) -> jax.Array:
        if self.outputDtype is None or self.outputDtype == "string":
            return y
        if T.is_string_col(y):
            from . import strops

            return strops.string_to_number(y, self.outputDtype)
        return y.astype(jnp.dtype(self.outputDtype))

    # ---- planner protocol (see repro.core.plan) ---------------------------
    def plan_hash_seeds(self) -> Optional[List[int]]:
        """fnv1a64 seeds this stage consumes per (stringified) input column,
        or None if the stage does not hash.  Stages returning seeds must also
        implement :meth:`apply_hashed`; the planner then computes each
        (column, seed) hash once and shares it across stages."""
        return None

    def apply_hashed(self, weights, inputs, hashes):
        """Like ``apply`` but with precomputed hashes: ``hashes[i][j]`` is the
        uint64 fnv1a64 of the string view of ``inputs[i]`` under
        ``plan_hash_seeds()[j]``."""
        raise NotImplementedError

    # ---- serialisation ----------------------------------------------------
    def config(self) -> Dict[str, Any]:
        cfg = dataclasses.asdict(self)
        cfg = {k: (list(v) if isinstance(v, tuple) else v) for k, v in cfg.items()}
        return cfg

    @classmethod
    def from_config(cls, cfg: Dict[str, Any]) -> "Stage":
        return cls(**cfg)


@dataclasses.dataclass
class Transformer(Stage):
    """A stateless stage: weights are empty, usable immediately."""

    needs_fit = False

    def apply(self, weights: Dict[str, jax.Array], inputs: Tuple[jax.Array, ...]) -> Tuple[jax.Array, ...]:
        raise NotImplementedError

    # Convenience eager path (engine/pipeline use apply directly).
    def transform(self, batch: T.Batch) -> T.Batch:
        ins = tuple(self._coerce(batch[n]) for n in self.input_names)
        outs = self.apply({}, ins)
        outs = tuple(self._coerce_out(o) for o in outs)
        res = dict(batch)
        res.update(dict(zip(self.output_names, outs)))
        return res

    def weights(self) -> Dict[str, jax.Array]:
        return {}


@dataclasses.dataclass
class Estimator(Stage):
    """A stage that must be fit: learns ``weights`` from data statistics.

    The statistics triple (init/update/merge) forms a commutative monoid so the
    engine may stream batches in any order and reduce across shards.
    ``finalize`` runs once on the host (stats tables are small) and produces
    the weights consumed by ``apply``.
    """

    needs_fit = True

    def init_stats(self):
        raise NotImplementedError

    def update_stats(self, stats, inputs: Tuple[jax.Array, ...]):
        raise NotImplementedError

    def merge_stats(self, a, b):
        raise NotImplementedError

    def finalize(self, stats) -> Dict[str, jax.Array]:
        raise NotImplementedError

    def apply(self, weights: Dict[str, jax.Array], inputs: Tuple[jax.Array, ...]) -> Tuple[jax.Array, ...]:
        raise NotImplementedError

    def fit_batch(self, batch: T.Batch) -> "FittedStage":
        """Single-batch convenience fit (tests, small data)."""
        ins = tuple(self._coerce(batch[n]) for n in self.input_names)
        stats = self.update_stats(self.init_stats(), ins)
        return FittedStage(self, self.finalize(stats))


class FittedStage:
    """An estimator bound to its learned weights; behaves like a Transformer."""

    needs_fit = False

    def __init__(self, stage: Stage, weights: Dict[str, jax.Array]):
        self.stage = stage
        self._weights = {k: jnp.asarray(v) for k, v in weights.items()}

    # mirror the Stage interface --------------------------------------------
    @property
    def input_names(self):
        return self.stage.input_names

    @property
    def output_names(self):
        return self.stage.output_names

    @property
    def name(self):
        return self.stage.name

    def weights(self) -> Dict[str, jax.Array]:
        return self._weights

    def apply(self, weights, inputs):
        return self.stage.apply(weights, inputs)

    def plan_hash_seeds(self):
        return self.stage.plan_hash_seeds()

    def apply_hashed(self, weights, inputs, hashes):
        return self.stage.apply_hashed(weights, inputs, hashes)

    def _coerce(self, x):
        return self.stage._coerce(x)

    def _coerce_out(self, y):
        return self.stage._coerce_out(y)

    def transform(self, batch: T.Batch) -> T.Batch:
        ins = tuple(self._coerce(batch[n]) for n in self.input_names)
        outs = self.apply(self._weights, ins)
        outs = tuple(self._coerce_out(o) for o in outs)
        res = dict(batch)
        res.update(dict(zip(self.output_names, outs)))
        return res

    def config(self):
        return self.stage.config()


def stage_from_config(op_name: str, cfg: Dict[str, Any], weights: Dict[str, Any]):
    """Reconstruct a (fitted) stage from serialised form."""
    cls = STAGE_REGISTRY[op_name]
    stage = cls.from_config(cfg)
    if weights:
        return FittedStage(stage, weights)
    return stage
