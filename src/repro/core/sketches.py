"""Mergeable, fixed-shape statistics sketches for distributed fitting.

Spark fits estimators with treeAggregate over partitions; the JAX analogue
needs statistics that are (a) fixed-shape pytrees (jit/pjit-able), (b) a
commutative monoid (mergeable across shards in any order).  This module
provides the two non-trivial ones:

* :class:`VocabTable` — a heavy-hitter (hash, count, byte-representative)
  table with capacity-C space-saving eviction.  EXACT whenever the number of
  distinct values is <= capacity (the common vocab case); an approximate
  top-C frequency sketch beyond that, as is standard for big-data vocab jobs.

* DDSketch-style log-binned histogram — relative-error quantiles (median
  imputation, quantile binning) with a fixed 2048-bin layout, mergeable by
  addition.

Both are pure jnp, so under pjit the per-shard updates run on the shard-local
slice and the replicated-output reduction becomes XLA all-reduces — the same
communication shape as Spark's treeAggregate.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

UINT64_MAX = jnp.uint64(0xFFFFFFFFFFFFFFFF)

# ---------------------------------------------------------------------------
# Vocab (heavy-hitter) table
# ---------------------------------------------------------------------------


def vocab_init(capacity: int, max_len: int) -> Dict[str, jax.Array]:
    return {
        "keys": jnp.full((capacity,), UINT64_MAX, jnp.uint64),
        "counts": jnp.zeros((capacity,), jnp.int64),
        "reps": jnp.zeros((capacity, max_len), jnp.uint8),
    }


def _aggregate_sorted(
    keys: jax.Array, counts: jax.Array, reps: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Combine duplicate keys of an unsorted (key,count,rep) multiset.

    Returns arrays of the SAME length with unique keys first (sorted asc),
    empty slots (key=UINT64_MAX, count=0) at the end.
    """
    n = keys.shape[0]
    order = jnp.argsort(keys)
    k = keys[order]
    c = counts[order]
    r = reps[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), k[1:] != k[:-1]])
    # empty slots (UINT64_MAX) must not create a segment of their own weight
    valid = k != UINT64_MAX
    seg = jnp.cumsum(is_first.astype(jnp.int64)) - 1
    agg_counts = jnp.zeros((n,), jnp.int64).at[seg].add(jnp.where(valid, c, 0))
    first_pos = jnp.full((n,), n, jnp.int64).at[seg].min(jnp.arange(n, dtype=jnp.int64))
    first_pos = jnp.clip(first_pos, 0, n - 1)
    out_keys = jnp.where(jnp.arange(n) <= seg[-1], k[first_pos], UINT64_MAX)
    out_keys = jnp.where(agg_counts > 0, out_keys, UINT64_MAX)
    out_counts = jnp.where(out_keys != UINT64_MAX, agg_counts, 0)
    out_reps = jnp.where((out_keys != UINT64_MAX)[:, None], r[first_pos], 0)
    return out_keys, out_counts, out_reps


def _evict_to_capacity(keys, counts, reps, capacity: int):
    """Keep the ``capacity`` highest-count entries (ties: smaller key)."""
    neg = -counts
    order = jnp.lexsort((keys, neg))  # primary: count desc, secondary: key asc
    keys, counts, reps = keys[order[:capacity]], counts[order[:capacity]], reps[order[:capacity]]
    # canonical layout: sorted by key, empties last
    o2 = jnp.argsort(keys)
    return {"keys": keys[o2], "counts": counts[o2], "reps": reps[o2]}


def vocab_update(
    table: Dict[str, jax.Array],
    hashes: jax.Array,
    reps: jax.Array,
    weights: Optional[jax.Array] = None,
) -> Dict[str, jax.Array]:
    """Fold a batch of (hash, byte-rep) observations into the table."""
    capacity = table["keys"].shape[0]
    h = hashes.reshape(-1)
    r = reps.reshape(-1, reps.shape[-1])
    w = weights.reshape(-1).astype(jnp.int64) if weights is not None else jnp.ones(h.shape, jnp.int64)
    if r.shape[-1] != table["reps"].shape[-1]:
        pad = table["reps"].shape[-1] - r.shape[-1]
        r = r[..., : table["reps"].shape[-1]] if pad < 0 else jnp.pad(r, ((0, 0), (0, pad)))
    keys = jnp.concatenate([table["keys"], h])
    counts = jnp.concatenate([table["counts"], w])
    reps_all = jnp.concatenate([table["reps"], r])
    k, c, rr = _aggregate_sorted(keys, counts, reps_all)
    return _evict_to_capacity(k, c, rr, capacity)


def vocab_merge(a: Dict[str, jax.Array], b: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    capacity = a["keys"].shape[0]
    k, c, r = _aggregate_sorted(
        jnp.concatenate([a["keys"], b["keys"]]),
        jnp.concatenate([a["counts"], b["counts"]]),
        jnp.concatenate([a["reps"], b["reps"]]),
    )
    return _evict_to_capacity(k, c, r, capacity)


# ---------------------------------------------------------------------------
# DDSketch-lite quantile histogram
# ---------------------------------------------------------------------------

DD_BINS = 2048
_GAMMA = 1.04
_HALF = DD_BINS // 2  # [0, _HALF) negative magnitudes, _HALF zero-ish, rest positive
_LOG_GAMMA = float(jnp.log(_GAMMA))
_MAG_BINS = _HALF - 1  # magnitude bins per sign
_MIN_EXP = -_MAG_BINS // 2  # symmetric exponent coverage ~ gamma^±512 ≈ 1e±8.7


def dd_init() -> jax.Array:
    return jnp.zeros((DD_BINS,), jnp.int64)


def _mag_bin(x_abs: jax.Array) -> jax.Array:
    e = jnp.floor(jnp.log(jnp.maximum(x_abs, 1e-300)) / _LOG_GAMMA).astype(jnp.int64)
    return jnp.clip(e - _MIN_EXP, 0, _MAG_BINS - 1)


def dd_update(hist: jax.Array, x: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    xf = x.reshape(-1).astype(jnp.float64)
    m = mask.reshape(-1) if mask is not None else jnp.ones(xf.shape, bool)
    m = m & ~jnp.isnan(xf)
    is_zero = jnp.abs(xf) < 1e-12
    mag = _mag_bin(jnp.abs(xf))
    idx = jnp.where(
        is_zero, _HALF, jnp.where(xf > 0, _HALF + 1 + mag, _HALF - 1 - mag)
    )
    idx = jnp.where(m, idx, DD_BINS)  # dropped
    return hist.at[idx].add(1, mode="drop")


def dd_merge(a: jax.Array, b: jax.Array) -> jax.Array:
    # plain addition: works for jnp histograms under jit AND for the numpy
    # host-side histograms produced by dd_update_np (serving telemetry)
    return a + b


def dd_bin_np(x) -> "np.ndarray":
    """Numpy mirror of :func:`dd_update`'s bin mapping (same 2048-bin layout).

    Host-side recorders (the serving gateway's per-request latency telemetry)
    cannot afford a jit dispatch per observation; this computes the identical
    bin index with numpy, so the resulting histograms are mergeable with
    :func:`dd_merge` and queryable with :func:`dd_quantile` alongside the jnp
    path — asserted bin-for-bin by tests/test_sketches.py."""
    import numpy as np

    xf = np.asarray(x, np.float64)
    is_zero = np.abs(xf) < 1e-12
    e = np.floor(
        np.log(np.maximum(np.abs(xf), 1e-300)) / _LOG_GAMMA
    ).astype(np.int64)
    mag = np.clip(e - _MIN_EXP, 0, _MAG_BINS - 1)
    return np.where(is_zero, _HALF, np.where(xf > 0, _HALF + 1 + mag, _HALF - 1 - mag))


def dd_init_np():
    """Numpy histogram with the dd_init layout (host-side telemetry)."""
    import numpy as np

    return np.zeros((DD_BINS,), np.int64)


def dd_update_np(hist, x):
    """In-place numpy fold of observations into ``hist`` (NaNs dropped,
    matching dd_update's mask semantics).  Returns ``hist``."""
    import numpy as np

    xf = np.asarray(x, np.float64).reshape(-1)
    xf = xf[~np.isnan(xf)]
    if xf.size:
        np.add.at(hist, dd_bin_np(xf), 1)
    return hist


def dd_quantile(hist: jax.Array, q) -> jax.Array:
    """Approximate quantile(s) with ~4% relative error (vectorised over q).

    An EMPTY histogram has no quantiles: every requested q yields NaN (jit-safe
    via where), never a garbage bin-0 value — callers (imputers, the serving
    cost model) must not mistake "no data" for "about -7e8"."""
    q = jnp.atleast_1d(jnp.asarray(q, jnp.float64))
    total = jnp.sum(hist)
    cum = jnp.cumsum(hist)
    target = q * total.astype(jnp.float64)
    bin_idx = jnp.searchsorted(cum.astype(jnp.float64), target, side="left")
    bin_idx = jnp.clip(bin_idx, 0, DD_BINS - 1)

    def value_of(i):
        mag_pos = i - _HALF - 1
        mag_neg = _HALF - 1 - i
        vpos = jnp.exp((mag_pos + _MIN_EXP + 0.5) * _LOG_GAMMA)
        vneg = -jnp.exp((mag_neg + _MIN_EXP + 0.5) * _LOG_GAMMA)
        return jnp.where(i == _HALF, 0.0, jnp.where(i > _HALF, vpos, vneg))

    return jnp.where(total == 0, jnp.float64(jnp.nan), value_of(bin_idx))


def dd_quantile_np(hist, q) -> "np.ndarray":
    """Numpy mirror of :func:`dd_quantile` for host-side callers.

    The gateway's cost model queries an estimate on every batch formation and
    every admission decision; a jnp dispatch there would cost more than the
    scheduling decision it informs.  Same bin layout, same NaN-on-empty
    semantics — parity asserted by tests/test_sketches.py."""
    import numpy as np

    q = np.atleast_1d(np.asarray(q, np.float64))
    h = np.asarray(hist)
    total = h.sum()
    if total == 0:
        return np.full(q.shape, np.nan)
    cum = np.cumsum(h).astype(np.float64)
    idx = np.searchsorted(cum, q * float(total), side="left")
    idx = np.clip(idx, 0, DD_BINS - 1)
    mag_pos = idx - _HALF - 1
    mag_neg = _HALF - 1 - idx
    vpos = np.exp((mag_pos + _MIN_EXP + 0.5) * _LOG_GAMMA)
    vneg = -np.exp((mag_neg + _MIN_EXP + 0.5) * _LOG_GAMMA)
    return np.where(idx == _HALF, 0.0, np.where(idx > _HALF, vpos, vneg))


# ---------------------------------------------------------------------------
# Moments (count / sum / sum-of-squares), elementwise over the trailing axes
# ---------------------------------------------------------------------------


def moments_init(feature_shape: tuple) -> Dict[str, jax.Array]:
    return {
        "count": jnp.zeros(feature_shape, jnp.float64),
        "sum": jnp.zeros(feature_shape, jnp.float64),
        "sumsq": jnp.zeros(feature_shape, jnp.float64),
        "min": jnp.full(feature_shape, jnp.inf, jnp.float64),
        "max": jnp.full(feature_shape, -jnp.inf, jnp.float64),
    }


def moments_update(m: Dict[str, jax.Array], x: jax.Array, mask=None) -> Dict[str, jax.Array]:
    fs = m["sum"].shape
    xf = x.astype(jnp.float64).reshape((-1,) + fs)
    msk = (mask.reshape((-1,) + fs) if mask is not None else jnp.ones(xf.shape, bool)) & ~jnp.isnan(xf)
    x0 = jnp.where(msk, xf, 0.0)
    return {
        "count": m["count"] + jnp.sum(msk, axis=0),
        "sum": m["sum"] + jnp.sum(x0, axis=0),
        "sumsq": m["sumsq"] + jnp.sum(x0 * x0, axis=0),
        "min": jnp.minimum(m["min"], jnp.min(jnp.where(msk, xf, jnp.inf), axis=0)),
        "max": jnp.maximum(m["max"], jnp.max(jnp.where(msk, xf, -jnp.inf), axis=0)),
    }


def moments_merge(a, b):
    return {
        "count": a["count"] + b["count"],
        "sum": a["sum"] + b["sum"],
        "sumsq": a["sumsq"] + b["sumsq"],
        "min": jnp.minimum(a["min"], b["min"]),
        "max": jnp.maximum(a["max"], b["max"]),
    }
