"""Mathematical transformers (paper §2: "mathematical ... operations").

All ops broadcast over arbitrary leading dims, so they apply equally to
scalar features, ``(batch, list)`` ranking features and nested sequences —
the paper's "nested-sequence-native" property falls out of jnp broadcasting.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..stage import Transformer, register_stage


@register_stage
@dataclasses.dataclass
class LogTransformer(Transformer):
    """log(x + alpha); the paper's LTR pipeline log-transforms wide-range
    numericals (alpha=1 gives log1p)."""

    alpha: float = 0.0
    base: Optional[float] = None  # natural log if None

    def apply(self, weights, inputs):
        (x,) = inputs
        y = jnp.log(x + self.alpha)
        if self.base is not None:
            y = y / jnp.log(jnp.asarray(self.base, y.dtype))
        return (y,)


@register_stage
@dataclasses.dataclass
class ExpTransformer(Transformer):
    def apply(self, weights, inputs):
        (x,) = inputs
        return (jnp.exp(x),)


@register_stage
@dataclasses.dataclass
class PowerTransformer(Transformer):
    exponent: float = 2.0

    def apply(self, weights, inputs):
        (x,) = inputs
        return (jnp.power(x, self.exponent),)


@register_stage
@dataclasses.dataclass
class AbsoluteValueTransformer(Transformer):
    def apply(self, weights, inputs):
        (x,) = inputs
        return (jnp.abs(x),)


@register_stage
@dataclasses.dataclass
class ClipTransformer(Transformer):
    minValue: Optional[float] = None
    maxValue: Optional[float] = None

    def apply(self, weights, inputs):
        (x,) = inputs
        return (jnp.clip(x, self.minValue, self.maxValue),)


@register_stage
@dataclasses.dataclass
class RoundTransformer(Transformer):
    mode: str = "round"  # round | floor | ceil

    def apply(self, weights, inputs):
        (x,) = inputs
        f = {"round": jnp.round, "floor": jnp.floor, "ceil": jnp.ceil}[self.mode]
        return (f(x),)


@register_stage
@dataclasses.dataclass
class ScaleTransformer(Transformer):
    """y = x * multiplier + offset (fixed affine, no fitting)."""

    multiplier: float = 1.0
    offset: float = 0.0

    def apply(self, weights, inputs):
        (x,) = inputs
        return (x * self.multiplier + self.offset,)


@register_stage
@dataclasses.dataclass
class StandardScoreTransformer(Transformer):
    """(x - mean) / std with *fixed* constants; the learned version is
    StandardScaleEstimator."""

    mean: float = 0.0
    std: float = 1.0

    def apply(self, weights, inputs):
        (x,) = inputs
        return ((x - self.mean) / self.std,)


@register_stage
@dataclasses.dataclass
class BucketizeTransformer(Transformer):
    """Static-splits bucketing: index i s.t. splits[i-1] <= x < splits[i]."""

    splits: Sequence[float] = ()

    def apply(self, weights, inputs):
        (x,) = inputs
        splits = jnp.asarray(list(self.splits), jnp.float64)
        return (jnp.searchsorted(splits, x.astype(jnp.float64), side="right").astype(jnp.int64),)


_BINARY = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "mod": jnp.mod,
    "pow": jnp.power,
}


@register_stage
@dataclasses.dataclass
class MathBinaryTransformer(Transformer):
    """Elementwise binary op of two columns, or of a column and a constant."""

    op: str = "add"
    constant: Optional[float] = None  # if set, second operand is a constant

    def apply(self, weights, inputs):
        f = _BINARY[self.op]
        if self.constant is not None:
            (x,) = inputs
            return (f(x, jnp.asarray(self.constant, x.dtype)),)
        x, y = inputs
        return (f(x, y),)
