"""Logical / conditional transformers (paper §2 "logical ... and conditional
operations").  NaN is the null sentinel for float columns."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from ..stage import Transformer, register_stage

_CMP = {
    "gt": jnp.greater,
    "ge": jnp.greater_equal,
    "lt": jnp.less,
    "le": jnp.less_equal,
    "eq": jnp.equal,
    "ne": jnp.not_equal,
}


@register_stage
@dataclasses.dataclass
class ComparisonTransformer(Transformer):
    op: str = "gt"
    constant: Optional[float] = None

    def apply(self, weights, inputs):
        f = _CMP[self.op]
        if self.constant is not None:
            (x,) = inputs
            return (f(x, self.constant),)
        x, y = inputs
        return (f(x, y),)


@register_stage
@dataclasses.dataclass
class LogicalTransformer(Transformer):
    op: str = "and"  # and | or | not | xor

    def apply(self, weights, inputs):
        if self.op == "not":
            (x,) = inputs
            return (~x.astype(bool),)
        x, y = (i.astype(bool) for i in inputs)
        f = {"and": jnp.logical_and, "or": jnp.logical_or, "xor": jnp.logical_xor}[self.op]
        return (f(x, y),)


@register_stage
@dataclasses.dataclass
class IfThenElseTransformer(Transformer):
    """inputCols = [condition, then, else] -> where(condition, then, else)."""

    def apply(self, weights, inputs):
        c, t, e = inputs
        return (jnp.where(c.astype(bool), t, e),)


@register_stage
@dataclasses.dataclass
class IsNullTransformer(Transformer):
    """True where the value is null (NaN for floats, sentinel for ints)."""

    intSentinel: Optional[int] = None

    def apply(self, weights, inputs):
        (x,) = inputs
        if jnp.issubdtype(x.dtype, jnp.floating):
            return (jnp.isnan(x),)
        if self.intSentinel is None:
            return (jnp.zeros(x.shape, bool),)
        return (x == self.intSentinel,)


@register_stage
@dataclasses.dataclass
class CoalesceTransformer(Transformer):
    """Replace nulls (NaN / sentinel) with a fill value."""

    fillValue: float = 0.0
    intSentinel: Optional[int] = None

    def apply(self, weights, inputs):
        (x,) = inputs
        if jnp.issubdtype(x.dtype, jnp.floating):
            return (jnp.where(jnp.isnan(x), jnp.asarray(self.fillValue, x.dtype), x),)
        if self.intSentinel is None:
            return (x,)
        return (jnp.where(x == self.intSentinel, jnp.asarray(int(self.fillValue), x.dtype), x),)
