"""Kamae transformer suite: stateless, rank-polymorphic column ops.

Grouped as in the paper §2 "Basic Functionalities": mathematical, string,
date, logical, array/list and conditional operations.  Every transformer maps
one-to-one onto a node of the exported inference graph.
"""
from .math import (
    AbsoluteValueTransformer,
    BucketizeTransformer,
    ClipTransformer,
    ExpTransformer,
    LogTransformer,
    MathBinaryTransformer,
    PowerTransformer,
    RoundTransformer,
    ScaleTransformer,
    StandardScoreTransformer,
)
from .string import (
    BloomEncodeTransformer,
    HashIndexTransformer,
    StringCaseTransformer,
    StringConcatTransformer,
    StringContainsTransformer,
    StringReplaceCharTransformer,
    StringStripTransformer,
    StringToStringListTransformer,
    SubstringTransformer,
)
from .date import (
    DateAddTransformer,
    DateDiffTransformer,
    DatePartTransformer,
    StringToDateTransformer,
)
from .array import (
    ArrayAggregateTransformer,
    ArrayConcatTransformer,
    ArraySliceTransformer,
    OneHotTransformer,
    VectorAssembleTransformer,
    VectorDisassembleTransformer,
)
from .logical import (
    CoalesceTransformer,
    ComparisonTransformer,
    IfThenElseTransformer,
    IsNullTransformer,
    LogicalTransformer,
)

__all__ = [n for n in dir() if n.endswith("Transformer")]
