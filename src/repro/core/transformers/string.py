"""String transformers over uint8 byte tensors (paper §2 string ops +
hash/bloom indexing, which are stateless and therefore transformers)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

from .. import hashing, strops
from .. import types as T
from ..stage import Transformer, register_stage


@register_stage
@dataclasses.dataclass
class HashIndexTransformer(Transformer):
    """Map (possibly non-string) ids into ``[offset, offset+numBins)`` via
    seeded 64-bit hashing — Listing 1's user_hash_indexer."""

    numBins: int = 1 << 16
    seed: int = 0
    indexOffset: int = 0  # reserve low indices (e.g. 0 for padding/mask)

    def apply(self, weights, inputs):
        (x,) = inputs
        if T.is_string_col(x):
            idx = hashing.hash_to_bins_routed(x, self.numBins, self.seed)
        else:
            idx = hashing.int_to_bins(x, self.numBins, self.seed)
        return (idx + self.indexOffset,)

    # planner protocol: bins derive from one shared fnv1a64(seed) hash.  Only
    # valid for string inputs (numeric ids use splitmix, not FNV) — the
    # planner falls back to ``apply`` when the input is not a byte column.
    plan_hash_stringify = False

    def plan_hash_seeds(self):
        return [self.seed]

    def apply_hashed(self, weights, inputs, hashes):
        h = hashes[0][0]
        idx = (hashing.fold32(h) % jnp.uint32(self.numBins)).astype(jnp.int64)
        return (idx + self.indexOffset,)


@register_stage
@dataclasses.dataclass
class BloomEncodeTransformer(Transformer):
    """Bloom encoding [9]: numHashes independent bins per value, enabling
    memory-efficient embeddings of huge-cardinality categoricals.  Output has
    one extra trailing axis of size numHashes."""

    numBins: int = 1 << 16
    numHashes: int = 3
    indexOffset: int = 0
    useKernel: bool = False  # route through the Pallas hot path

    def apply(self, weights, inputs):
        (x,) = inputs
        if not T.is_string_col(x):
            x = strops.number_to_string(x, self.maxLen)
        if self.useKernel:
            from repro.kernels.bloom_hash import ops as khash

            idx = khash.bloom_indices(x, self.numBins, self.numHashes)
        else:
            idx = hashing.bloom_indices_routed(x, self.numBins, self.numHashes)
        return (idx + self.indexOffset,)

    # planner protocol: numHashes seeded hashes per input, shared via the
    # plan; numeric ids hash through their decimal-string widening (as apply)
    plan_hash_stringify = True

    def plan_hash_seeds(self):
        return list(range(self.numHashes))

    def apply_hashed(self, weights, inputs, hashes):
        hs = hashes[0]
        idx = jnp.stack(
            [
                (hashing.fold32(h) % jnp.uint32(self.numBins)).astype(jnp.int64)
                for h in hs
            ],
            axis=-1,
        )
        return (idx + self.indexOffset,)


@register_stage
@dataclasses.dataclass
class StringToStringListTransformer(Transformer):
    """Split on a delimiter into a fixed-length padded list (Listing 1's
    genres_split_to_array_transform)."""

    separator: str = ","
    listLength: int = 8
    defaultValue: Optional[str] = None
    outMaxLen: Optional[int] = None

    def apply(self, weights, inputs):
        (x,) = inputs
        return (
            strops.split_to_list(
                x, self.separator, self.listLength, self.defaultValue, self.outMaxLen
            ),
        )


@register_stage
@dataclasses.dataclass
class StringCaseTransformer(Transformer):
    case: str = "lower"  # lower | upper

    def apply(self, weights, inputs):
        (x,) = inputs
        return (strops.lower(x) if self.case == "lower" else strops.upper(x),)


@register_stage
@dataclasses.dataclass
class StringConcatTransformer(Transformer):
    separator: str = ""
    outMaxLen: int = T.DEFAULT_MAX_LEN

    def apply(self, weights, inputs):
        return (strops.concat(list(inputs), self.separator, self.outMaxLen),)


@register_stage
@dataclasses.dataclass
class SubstringTransformer(Transformer):
    start: int = 0
    length: int = 1

    def apply(self, weights, inputs):
        (x,) = inputs
        return (strops.substring(x, self.start, self.length),)


@register_stage
@dataclasses.dataclass
class StringContainsTransformer(Transformer):
    pattern: str = ""
    mode: str = "contains"  # contains | startswith | endswith

    def apply(self, weights, inputs):
        (x,) = inputs
        f = {
            "contains": strops.contains,
            "startswith": strops.startswith,
            "endswith": strops.endswith,
        }[self.mode]
        return (f(x, self.pattern),)


@register_stage
@dataclasses.dataclass
class StringStripTransformer(Transformer):
    stripChar: str = " "

    def apply(self, weights, inputs):
        (x,) = inputs
        return (strops.strip_char(x, self.stripChar),)


@register_stage
@dataclasses.dataclass
class StringReplaceCharTransformer(Transformer):
    oldChar: str = " "
    newChar: str = "_"

    def apply(self, weights, inputs):
        (x,) = inputs
        return (strops.replace_char(x, self.oldChar, self.newChar),)
