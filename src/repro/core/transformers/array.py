"""Array / list transformers (paper §2 "array, list" ops; §3: "selected
numerical features are assembled into a single array which is subsequently
standard scaled and disassembled into original features")."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

from ..stage import Transformer, register_stage


@register_stage
@dataclasses.dataclass
class VectorAssembleTransformer(Transformer):
    """Stack N scalar columns into one (..., N) array column."""

    def apply(self, weights, inputs):
        common = jnp.result_type(*[x.dtype for x in inputs])
        return (jnp.stack([x.astype(common) for x in inputs], axis=-1),)


@register_stage
@dataclasses.dataclass
class VectorDisassembleTransformer(Transformer):
    """Split an (..., N) array column back into N scalar columns."""

    def apply(self, weights, inputs):
        (x,) = inputs
        n = len(self.output_names)
        if x.shape[-1] != n:
            raise ValueError(
                f"{self.name}: array width {x.shape[-1]} != {n} outputCols"
            )
        return tuple(x[..., i] for i in range(n))


@register_stage
@dataclasses.dataclass
class ArrayAggregateTransformer(Transformer):
    """Aggregate over a list axis (paper: 'applied at the sequence level').

    ``maskValue`` excludes padding from the aggregate (e.g. PADDED genres).
    """

    op: str = "mean"  # sum | mean | max | min | count
    axis: int = -1
    maskValue: Optional[float] = None

    def apply(self, weights, inputs):
        (x,) = inputs
        if self.maskValue is not None:
            m = x != self.maskValue
        else:
            m = jnp.ones_like(x, bool)
        xf = x.astype(jnp.float64)
        cnt = jnp.sum(m, axis=self.axis)
        if self.op == "count":
            return (cnt.astype(jnp.int64),)
        if self.op == "sum":
            return (jnp.sum(jnp.where(m, xf, 0), axis=self.axis),)
        if self.op == "mean":
            s = jnp.sum(jnp.where(m, xf, 0), axis=self.axis)
            return (s / jnp.maximum(cnt, 1),)
        if self.op == "max":
            return (jnp.max(jnp.where(m, xf, -jnp.inf), axis=self.axis),)
        if self.op == "min":
            return (jnp.min(jnp.where(m, xf, jnp.inf), axis=self.axis),)
        raise ValueError(f"unknown aggregate {self.op!r}")


@register_stage
@dataclasses.dataclass
class ArrayConcatTransformer(Transformer):
    """Concatenate array columns along the last axis."""

    def apply(self, weights, inputs):
        common = jnp.result_type(*[x.dtype for x in inputs])
        return (jnp.concatenate([x.astype(common) for x in inputs], axis=-1),)


@register_stage
@dataclasses.dataclass
class ArraySliceTransformer(Transformer):
    start: int = 0
    length: int = 1
    axis: int = -1

    def apply(self, weights, inputs):
        (x,) = inputs
        idx = [slice(None)] * x.ndim
        idx[self.axis] = slice(self.start, self.start + self.length)
        return (x[tuple(idx)],)


@register_stage
@dataclasses.dataclass
class OneHotTransformer(Transformer):
    """Fixed-depth one-hot of an integer index column (the learned-vocabulary
    version is OneHotEncodeEstimator)."""

    depth: int = 2
    dtype: str = "float32"

    def apply(self, weights, inputs):
        (x,) = inputs
        eye = (x[..., None] == jnp.arange(self.depth)).astype(jnp.dtype(self.dtype))
        return (eye,)
