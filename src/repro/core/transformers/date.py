"""Date transformers (paper §3: "date features are disassembled into parts,
e.g. month, weekday ... particular dates are subtracted to generate
durations").  Dates are int days-since-epoch in-graph; StringToDateTransformer
parses the data-lake 'YYYY-MM-DD' format."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .. import strops
from ..stage import Transformer, register_stage


@register_stage
@dataclasses.dataclass
class StringToDateTransformer(Transformer):
    """'YYYY-MM-DD' uint8 strings -> int64 days since 1970-01-01."""

    def apply(self, weights, inputs):
        (x,) = inputs
        return (strops.parse_date(x),)


@register_stage
@dataclasses.dataclass
class DatePartTransformer(Transformer):
    """Extract a civil-calendar part from a days-since-epoch column."""

    part: str = "month"  # year | month | day | weekday | dayofyear

    def apply(self, weights, inputs):
        (d,) = inputs
        y, m, day = strops.civil_from_days(d)
        if self.part == "year":
            out = y
        elif self.part == "month":
            out = m
        elif self.part == "day":
            out = day
        elif self.part == "weekday":
            out = strops.weekday_from_days(d)
        elif self.part == "dayofyear":
            out = d - strops.days_from_civil(y, jnp.ones_like(m), jnp.ones_like(day)) + 1
        else:
            raise ValueError(f"unknown date part {self.part!r}")
        return (out.astype(jnp.int64),)


@register_stage
@dataclasses.dataclass
class DateDiffTransformer(Transformer):
    """days(inputCols[0]) - days(inputCols[1]) — the paper's durations."""

    def apply(self, weights, inputs):
        a, b = inputs
        return ((a - b).astype(jnp.int64),)


@register_stage
@dataclasses.dataclass
class DateAddTransformer(Transformer):
    days: int = 0

    def apply(self, weights, inputs):
        (d,) = inputs
        return ((d + self.days).astype(jnp.int64),)
