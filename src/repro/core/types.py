"""Core column types for the preprocessing framework.

The paper's Spark engine operates on typed columns; our JAX engine operates on
dict-of-array "columnar batches".  JAX has no string dtype, so strings are
represented TPU-natively as fixed-width ``uint8`` byte tensors with trailing
zero padding: a string column of logical shape ``(...,)`` is stored as a
``uint8`` array of shape ``(..., max_len)``.  Real strings never contain NUL,
so zero-padding is unambiguous; all string ops mask trailing zeros.

64-bit integer support is required for low-collision string hashing
(FNV-1a-64), so this module enables jax x64 mode on import.  All model code in
this repo passes explicit dtypes and is unaffected by the changed defaults.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Sequence, Union

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flag)
import numpy as np  # noqa: E402

# A columnar batch: column name -> array.  String columns carry one extra
# trailing byte axis relative to their logical shape.
Batch = Dict[str, jax.Array]

#: Default fixed width for string byte tensors.
DEFAULT_MAX_LEN = 32

_STRING_KIND = "string"
_NUMERIC_KINDS = ("float", "int", "bool")


@dataclasses.dataclass(frozen=True)
class ColumnSchema:
    """Schema of one column, mirroring the paper's tf_input_schema entries."""

    name: str
    dtype: str  # "float32" | "float64" | "int32" | "int64" | "bool" | "string"
    shape: tuple = ()  # logical shape EXCLUDING batch dim and byte axis
    max_len: int = DEFAULT_MAX_LEN  # byte width, string columns only

    @property
    def is_string(self) -> bool:
        return self.dtype == _STRING_KIND

    def jax_dtype(self):
        if self.is_string:
            return jnp.uint8
        return jnp.dtype(self.dtype)

    def array_shape(self, batch: int) -> tuple:
        s = (batch,) + tuple(self.shape)
        if self.is_string:
            s = s + (self.max_len,)
        return s


def is_string_col(arr: jax.Array) -> bool:
    """Heuristic used by rank-polymorphic ops: string cols are uint8."""
    return arr.dtype == jnp.uint8


# ---------------------------------------------------------------------------
# Host-side string <-> byte-tensor conversion (data-pipeline boundary only;
# never inside a jitted graph).
# ---------------------------------------------------------------------------

def encode_strings(values, max_len: int = DEFAULT_MAX_LEN) -> np.ndarray:
    """Encode (nested) lists / numpy arrays of python strings to uint8.

    Output shape = ``np.shape(values) + (max_len,)``.  UTF-8 bytes, truncated
    to ``max_len``, zero padded.
    """
    arr = np.asarray(values, dtype=object)
    flat = arr.reshape(-1)
    out = np.zeros((flat.size, max_len), dtype=np.uint8)
    for i, s in enumerate(flat):
        if s is None:
            continue
        b = str(s).encode("utf-8")[:max_len]
        out[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out.reshape(arr.shape + (max_len,))


def decode_strings(arr) -> np.ndarray:
    """Inverse of :func:`encode_strings` (for debugging / vocab export)."""
    a = np.asarray(arr, dtype=np.uint8)
    lead = a.shape[:-1]
    flat = a.reshape(-1, a.shape[-1])
    out = np.empty(flat.shape[0], dtype=object)
    for i, row in enumerate(flat):
        n = int(np.argmax(row == 0)) if (row == 0).any() else row.shape[0]
        out[i] = bytes(row[:n]).decode("utf-8", errors="replace")
    return out.reshape(lead) if lead else out[0]


def string_lengths(arr: jax.Array) -> jax.Array:
    """Length (in bytes) of every string in a uint8 string tensor."""
    return jnp.sum((arr != 0).astype(jnp.int32), axis=-1)


def strings_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise equality of two string tensors (broadcasts leading dims)."""
    return jnp.all(a == b, axis=-1)


def as_string_constant(s: str, max_len: int = DEFAULT_MAX_LEN) -> jnp.ndarray:
    """A single python string as a (max_len,) uint8 constant."""
    return jnp.asarray(encode_strings([s], max_len)[0])


def dtype_name(x) -> str:
    return str(jnp.asarray(x).dtype)


def cast_column(arr: jax.Array, dtype: str) -> jax.Array:
    """Cast a numeric column; 'string' casts are handled by dedicated ops."""
    if dtype == _STRING_KIND:
        raise TypeError("use NumberToString/StringToNumber transformers")
    return arr.astype(jnp.dtype(dtype))
