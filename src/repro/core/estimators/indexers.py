"""Learned indexers: string indexing (vocabulary lookup), shared indexing,
and the one-hot encoder built on top.

Index layout (Keras-StringLookup compatible, matching the paper's Listing 1):

    [0: maskToken]  [numOOVIndices OOV buckets]  [vocabulary...]

the mask slot exists only when ``maskToken`` is set.  Unseen values hash into
one of the OOV buckets; with ``numOOVIndices=0`` they fall back to index 0.

Lookup at inference is TPU-native: 64-bit hash of the byte tensor, then a
branchless binary search (``searchsorted``) in the sorted hash table — O(log V)
integer ops, no host dictionary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import hashing, sketches, strops
from .. import types as T
from ..stage import Estimator, register_stage


@register_stage
@dataclasses.dataclass
class StringIndexEstimator(Estimator):
    """Vocabulary indexer (Listing 1's movie_id_string_indexer)."""

    stringOrderType: str = "frequencyDesc"
    numOOVIndices: int = 1
    maskToken: Optional[str] = None
    maxVocabSize: Optional[int] = None
    vocabCapacity: int = 1 << 15  # sketch capacity; exact below this many uniques

    # ---- statistics monoid -------------------------------------------------
    def init_stats(self):
        return sketches.vocab_init(self.vocabCapacity, self.maxLen)

    def update_stats(self, stats, inputs):
        table = stats
        for x in inputs:
            if not T.is_string_col(x):
                x = strops.number_to_string(x, self.maxLen)
            h = hashing.fnv1a64_routed(x)
            table = sketches.vocab_update(table, h, x)
        return table

    def merge_stats(self, a, b):
        return sketches.vocab_merge(a, b)

    # ---- host-side finalisation -------------------------------------------
    def finalize(self, stats) -> Dict[str, jax.Array]:
        keys = np.asarray(stats["keys"])
        counts = np.asarray(stats["counts"])
        reps = np.asarray(stats["reps"])
        valid = keys != np.uint64(0xFFFFFFFFFFFFFFFF)
        keys, counts, reps = keys[valid], counts[valid], reps[valid]

        mask_hash = None
        if self.maskToken is not None:
            mask_hash = np.asarray(
                hashing.fnv1a64(jnp.asarray(T.encode_strings([self.maskToken], self.maxLen)))
            )[0]
            keep = keys != mask_hash
            keys, counts, reps = keys[keep], counts[keep], reps[keep]

        order_type = self.stringOrderType
        if order_type.startswith("frequency"):
            order = np.lexsort((keys, -counts if order_type.endswith("Desc") else counts))
        elif order_type.startswith("alphabetical"):
            dec = T.decode_strings(reps)
            order = np.argsort(dec, kind="stable")
            if order_type.endswith("Desc"):
                order = order[::-1]
        else:
            raise ValueError(f"unknown stringOrderType {order_type!r}")
        keys, counts, reps = keys[order], counts[order], reps[order]
        if self.maxVocabSize is not None:
            keys, counts, reps = (
                keys[: self.maxVocabSize],
                counts[: self.maxVocabSize],
                reps[: self.maxVocabSize],
            )

        base = (1 if self.maskToken is not None else 0) + self.numOOVIndices
        target = np.arange(len(keys), dtype=np.int64) + base
        # store sorted by hash for searchsorted lookup
        o = np.argsort(keys)
        weights = {
            "hash_keys": jnp.asarray(keys[o].astype(np.uint64)),
            "target_idx": jnp.asarray(target[o]),
            "vocab_bytes": jnp.asarray(reps[o]),
            "vocab_counts": jnp.asarray(counts[o]),
        }
        if mask_hash is not None:
            weights["mask_hash"] = jnp.asarray(np.uint64(mask_hash))
        return weights

    # ---- inference ----------------------------------------------------------
    @property
    def vocab_base(self) -> int:
        return (1 if self.maskToken is not None else 0) + self.numOOVIndices

    def vocab_size(self, weights) -> int:
        return self.vocab_base + int(weights["hash_keys"].shape[0])

    def _lookup(self, weights, x: jax.Array, h: Optional[jax.Array] = None) -> jax.Array:
        """Index lookup; ``h`` may carry a precomputed (planner-CSE'd) hash —
        the input bytes are only ever consumed through it."""
        if h is None:
            if not T.is_string_col(x):
                x = strops.number_to_string(x, self.maxLen)
            h = hashing.fnv1a64_routed(x)
        table = weights["hash_keys"]
        v = table.shape[0]
        pos = jnp.clip(jnp.searchsorted(table, h), 0, max(v - 1, 0))
        if v == 0:
            found = jnp.zeros(h.shape, bool)
            idx = jnp.zeros(h.shape, jnp.int64)
        else:
            found = table[pos] == h
            idx = weights["target_idx"][pos]
        oov_off = 1 if self.maskToken is not None else 0
        if self.numOOVIndices > 0:
            oov = (h % jnp.uint64(self.numOOVIndices)).astype(jnp.int64) + oov_off
        else:
            oov = jnp.zeros(h.shape, jnp.int64)
        out = jnp.where(found, idx, oov)
        if self.maskToken is not None:
            out = jnp.where(h == weights["mask_hash"], 0, out)
        return out

    def apply(self, weights, inputs):
        return tuple(self._lookup(weights, x) for x in inputs)

    # planner protocol: one seed-0 hash per input column, shared via the
    # plan; numeric ids are hashed through their decimal-string widening
    # (mirroring _lookup), so the planner may stringify on our behalf
    plan_hash_stringify = True

    def plan_hash_seeds(self):
        return [0]

    def apply_hashed(self, weights, inputs, hashes):
        return tuple(
            self._lookup(weights, x, h=hs[0]) for x, hs in zip(inputs, hashes)
        )


@register_stage
@dataclasses.dataclass
class SharedStringIndexEstimator(StringIndexEstimator):
    """One vocabulary built over, and applied to, multiple columns
    (paper §2 "shared string indexing").  Statistics already fold all
    inputCols; apply maps each column independently."""


@register_stage
@dataclasses.dataclass
class OneHotEncodeEstimator(StringIndexEstimator):
    """String-index then one-hot (Listing 1's occupation_one_hot_encoder).

    dropUnseen=True removes the OOV slots from the one-hot width, so unseen
    values encode as all-zeros (sklearn handle_unknown='ignore' semantics).
    """

    dropUnseen: bool = False
    oneHotDtype: str = "float32"

    def apply(self, weights, inputs):
        (x,) = inputs
        return (self._onehot(weights, self._lookup(weights, x)),)

    def apply_hashed(self, weights, inputs, hashes):
        (x,), (hs,) = inputs, hashes
        return (self._onehot(weights, self._lookup(weights, x, h=hs[0])),)

    def _onehot(self, weights, idx):
        base = self.vocab_base
        v = int(weights["hash_keys"].shape[0])
        if self.dropUnseen:
            # shift vocab down over the OOV slots; OOV -> negative -> all-zero
            mask_slots = 1 if self.maskToken is not None else 0
            idx = jnp.where(idx >= base, idx - self.numOOVIndices,
                            jnp.where(idx < mask_slots, idx, -1))
            depth = mask_slots + v
        else:
            depth = base + v
        return (idx[..., None] == jnp.arange(depth)).astype(jnp.dtype(self.oneHotDtype))
