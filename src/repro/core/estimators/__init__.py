"""Kamae estimators: stages that learn weights from data (paper §2:
"string-, hash-, bloom-, shared- indexing, standard scaling, and imputation";
quantile binning is §4 future work, implemented here as a beyond-paper item).
"""
from .indexers import (
    OneHotEncodeEstimator,
    SharedStringIndexEstimator,
    StringIndexEstimator,
)
from .scalers import (
    ImputeEstimator,
    MinMaxScaleEstimator,
    QuantileBinEstimator,
    StandardScaleEstimator,
)

__all__ = [
    "StringIndexEstimator",
    "SharedStringIndexEstimator",
    "OneHotEncodeEstimator",
    "StandardScaleEstimator",
    "MinMaxScaleEstimator",
    "ImputeEstimator",
    "QuantileBinEstimator",
]
