"""Learned numeric estimators: standard scaling, min-max scaling, imputation
and quantile binning.  Statistics are elementwise over the feature (trailing)
shape, reduced over all leading dims — matching the paper's LTR pattern of
"assemble into array -> standard scale -> disassemble".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import sketches
from ..stage import Estimator, register_stage


def _feature_shape(shape: Sequence[int]) -> Tuple[int, ...]:
    """Trailing feature shape used for per-element statistics: scalar columns
    aggregate to (), array columns to their last axis."""
    return tuple(shape[-1:]) if len(shape) >= 2 else ()


@register_stage
@dataclasses.dataclass
class StandardScaleEstimator(Estimator):
    """(x - mean) / std with mean/std learned over the data (per array slot)."""

    epsilon: float = 1e-7
    featureSize: Optional[int] = None  # None -> scalar column

    def _fshape(self):
        return () if self.featureSize is None else (self.featureSize,)

    def init_stats(self):
        return sketches.moments_init(self._fshape())

    def update_stats(self, stats, inputs):
        (x,) = inputs
        return sketches.moments_update(stats, x)

    def merge_stats(self, a, b):
        return sketches.moments_merge(a, b)

    def finalize(self, stats):
        cnt = jnp.maximum(stats["count"], 1.0)
        mean = stats["sum"] / cnt
        var = jnp.maximum(stats["sumsq"] / cnt - mean * mean, 0.0)
        return {"mean": mean, "std": jnp.sqrt(var + self.epsilon)}

    def apply(self, weights, inputs):
        (x,) = inputs
        dt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float64
        return (((x.astype(dt) - weights["mean"].astype(dt)) / weights["std"].astype(dt)),)


@register_stage
@dataclasses.dataclass
class MinMaxScaleEstimator(Estimator):
    """x -> (x - min) / (max - min), learned range."""

    featureSize: Optional[int] = None

    def _fshape(self):
        return () if self.featureSize is None else (self.featureSize,)

    def init_stats(self):
        return sketches.moments_init(self._fshape())

    def update_stats(self, stats, inputs):
        (x,) = inputs
        return sketches.moments_update(stats, x)

    def merge_stats(self, a, b):
        return sketches.moments_merge(a, b)

    def finalize(self, stats):
        span = jnp.maximum(stats["max"] - stats["min"], 1e-12)
        return {"min": stats["min"], "span": span}

    def apply(self, weights, inputs):
        (x,) = inputs
        dt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float64
        return (((x.astype(dt) - weights["min"].astype(dt)) / weights["span"].astype(dt)),)


@register_stage
@dataclasses.dataclass
class ImputeEstimator(Estimator):
    """Replace nulls (NaN) with a learned statistic (paper: "imputation").

    strategy='median' uses the DDSketch histogram (~4% relative error,
    mergeable across shards); 'mean' is exact.
    """

    strategy: str = "mean"  # mean | median | constant
    fillValue: float = 0.0  # for strategy='constant'

    def init_stats(self):
        return {"moments": sketches.moments_init(()), "hist": sketches.dd_init()}

    def update_stats(self, stats, inputs):
        (x,) = inputs
        return {
            "moments": sketches.moments_update(stats["moments"], x),
            "hist": sketches.dd_update(stats["hist"], x),
        }

    def merge_stats(self, a, b):
        return {
            "moments": sketches.moments_merge(a["moments"], b["moments"]),
            "hist": sketches.dd_merge(a["hist"], b["hist"]),
        }

    def finalize(self, stats):
        if self.strategy == "mean":
            fill = stats["moments"]["sum"] / jnp.maximum(stats["moments"]["count"], 1.0)
        elif self.strategy == "median":
            fill = sketches.dd_quantile(stats["hist"], 0.5)[0]
        elif self.strategy == "constant":
            fill = jnp.asarray(self.fillValue, jnp.float64)
        else:
            raise ValueError(f"unknown impute strategy {self.strategy!r}")
        return {"fill": fill}

    def apply(self, weights, inputs):
        (x,) = inputs
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return (x,)
        return (jnp.where(jnp.isnan(x), weights["fill"].astype(x.dtype), x),)


@register_stage
@dataclasses.dataclass
class QuantileBinEstimator(Estimator):
    """Equal-frequency binning with DDSketch quantile splits — named by the
    paper as planned "quantile binning" future work; beyond-paper deliverable.
    """

    numBuckets: int = 10

    def init_stats(self):
        return sketches.dd_init()

    def update_stats(self, stats, inputs):
        (x,) = inputs
        return sketches.dd_update(stats, x)

    def merge_stats(self, a, b):
        return sketches.dd_merge(a, b)

    def finalize(self, stats):
        qs = np.linspace(0, 1, self.numBuckets + 1)[1:-1]
        splits = sketches.dd_quantile(stats, jnp.asarray(qs))
        return {"splits": splits}

    def apply(self, weights, inputs):
        (x,) = inputs
        return (
            jnp.searchsorted(weights["splits"], x.astype(jnp.float64), side="right").astype(
                jnp.int64
            ),
        )
