"""PlanRunner — streaming, sharding-aware executor for TransformPlans.

The Spark-role offline transform ("apply the fitted pipeline to an epoch of
data") is the throughput path of the paper's bridge, and prior measurement
shows the input pipeline — not the kernels — dominates tabular preprocessing
cost once the per-batch graph is compiled.  ``FittedPipeline.transform_jit``
in a loop leaves three kinds of time on the floor:

  1. every batch blocks: host staging, device dispatch and result readback
     serialise instead of overlapping;
  2. every batch pays the full per-call fixed cost (host→device transfer
     setup, dispatch, output allocation) at whatever batch size the data
     lake handed us;
  3. the compiled executable is blind to meshes, so the offline sweep cannot
     reuse the serving path's plan (or vice versa).

``PlanRunner`` drives an entire batch iterator through ONE cached executable
of a :class:`~repro.core.plan.TransformPlan`:

* **Packing** — up to ``pack`` equal-shaped batches are concatenated on the
  host into one superbatch, amortising per-call fixed cost and giving XLA
  wider arrays (all pipeline stages are row-wise, so results are
  batch-for-batch identical — asserted by tests).  Leftover batches that
  don't fill a pack run through the same plan individually.
* **Double-buffered host→device staging** — packing + ``jax.device_put``
  run in a background thread ``prefetch`` superbatches ahead of compute, so
  host staging overlaps device execution.  With an ``engine`` (mesh), the
  device_put places each column with ``Engine.batch_sharding()`` and the
  executable is lowered with matching ``in_shardings`` — the pod-sharded
  offline sweep and the single-device serve path share one plan.
* **Donation** — staged input buffers are donated to the executable by
  default (they are private to the runner), letting XLA reuse them for
  outputs instead of allocating per batch.
* **Pinned staging** (optional, CPU default) — numpy columns concatenate
  directly into preallocated staging arrays before device_put, so
  steady-state streaming does no host allocation.  Slots cycle beyond the
  in-flight window; on CPU (the default-enabled backend) device_put copies
  synchronously, so a slot is always free by the time it cycles back.

The same staging helper (:func:`stage_batch`) backs the online
``MicroBatcher``, keeping offline and serving host→device handling unified.
"""
from __future__ import annotations

import collections
import os
import threading
import time
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

import jax
import numpy as np

from . import types as T


def stage_batch(batch, sharding=None):
    """Place one host batch on device, sharded when ``sharding`` is given.

    Shared by the offline PlanRunner and the online MicroBatcher so both
    paths stage identically (and a mesh-sharded serving tier needs only a
    sharding argument)."""
    if sharding is None:
        return {k: jax.device_put(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


def _autopack_default() -> bool:
    return os.environ.get("REPRO_RUNNER_AUTOPACK", "0") not in ("0", "", "false")


class _AutoPack:
    """Halve/double ``pack`` toward a per-superbatch latency target.

    The fixed ``pack=8`` default sits on a cache cliff for some hosts (see
    ROADMAP): too large a superbatch blows the cache and adds latency, too
    small leaves per-call fixed cost unamortised.  This controller measures
    superbatch wall time and walks ``pack`` toward ``target`` seconds per
    call: above the target it halves, below half the target it doubles, and
    inside the band (or at a bound) it settles — after which measurement
    stops and the runner returns to fully-async dispatch.

    The first measured superbatch is discarded: it pays compile cost and
    would otherwise always read as "too slow".  Leftover groups smaller than
    the current pack are ignored — they are not representative of a full
    superbatch.  ``observe`` is thread-safe (worker dispatch threads)."""

    def __init__(self, target_s: float, lo: int = 1, hi: int = 64):
        self.target = float(target_s)
        self.lo = int(lo)
        self.hi = int(hi)
        self.warmed = False
        self.settled = False
        self.adjustments = 0
        self._lock = threading.Lock()

    def observe(self, pack_used: int, current_pack: int, seconds: float) -> int:
        with self._lock:
            if self.settled:
                return current_pack
            if not self.warmed:
                self.warmed = True  # compile superbatch: never representative
                return current_pack
            if pack_used < current_pack:
                return current_pack  # under-full leftover group
            if seconds > self.target:
                new = max(self.lo, current_pack // 2)
            elif seconds < self.target / 2:
                new = min(self.hi, current_pack * 2)
            else:
                new = current_pack
            if new == current_pack:
                self.settled = True
            else:
                self.adjustments += 1
            return new


class PlanRunner:
    """Stream an entire batch iterator through one compiled TransformPlan.

    Args:
      plan: a :class:`~repro.core.plan.TransformPlan` (typically
        ``fitted.plan()`` or ``model.plan()``).
      engine: optional :class:`~repro.core.engine.Engine`; with a mesh, input
        columns are device_put with ``batch_sharding()`` and the executable
        is lowered with matching ``in_shardings``.
      donate: donate staged input buffers to the executable (default True —
        the staged superbatch is private to the runner).
      pack: number of equal-shaped input batches fused into one executable
        call.  1 disables packing.
      prefetch: how many staged superbatches the background staging thread
        keeps ahead of compute (double buffering at the default 2).
      staging: reuse pinned host staging arrays for numpy inputs.  None =
        auto (enabled on the CPU backend, where device_put copies
        synchronously and slot reuse is trivially safe).
      workers: concurrent compute dispatch streams.  None = auto (2 on the
        CPU backend, where XLA executions from distinct host threads run
        concurrently across cores; 1 elsewhere — an accelerator serializes
        compute on-device, so extra dispatch threads only add contention).
        Output order is preserved regardless.
      autopack: adapt ``pack`` at runtime from measured superbatch wall time
        (halve above ``autopack_target_ms``, double below half of it, settle
        in between — see :class:`_AutoPack`).  None = the
        ``REPRO_RUNNER_AUTOPACK=1`` env default (off).
      autopack_target_ms: target superbatch latency for autopack.  None =
        the ``REPRO_RUNNER_PACK_TARGET_MS`` env default (50 ms).
      clock: monotonic time source for autopack measurement (tests inject a
        fake clock; production uses ``time.perf_counter``).
      materialize: where yielded batches live.  "device" (default) yields
        device arrays (sliced per input batch when packed — each slice is a
        device op).  "host" transfers each computed superbatch to the host
        once and yields zero-copy numpy views per batch — the right mode for
        an offline sweep that writes results out, and much cheaper than
        per-batch device slicing when packing.
    """

    def __init__(
        self,
        plan,
        engine=None,
        donate: bool = True,
        pack: int = 8,
        prefetch: int = 2,
        staging: Optional[bool] = None,
        workers: Optional[int] = None,
        materialize: str = "device",
        autopack: Optional[bool] = None,
        autopack_target_ms: Optional[float] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        if materialize not in ("device", "host"):
            raise ValueError("materialize must be 'device' or 'host'")
        self.materialize = materialize
        if pack < 1:
            raise ValueError("pack must be >= 1")
        self.plan = plan
        self.engine = engine
        self.donate = donate
        self.pack = pack
        self.prefetch = max(int(prefetch), 0)
        if staging is None:
            staging = jax.default_backend() == "cpu"
        self.staging = staging
        if workers is None:
            workers = 2 if jax.default_backend() == "cpu" else 1
        self.workers = max(int(workers), 1)
        self._sharding = (
            engine.batch_sharding()
            if engine is not None and engine.mesh is not None
            else None
        )
        # outputs-constrained plans declare which raw columns they read; the
        # runner stages only those (the rest never cross host->device)
        req = getattr(plan, "required_inputs", lambda: None)()
        self._required = set(req) if req is not None else None
        self._clock = clock if clock is not None else time.perf_counter
        if autopack is None:
            autopack = _autopack_default()
        if autopack_target_ms is None:
            autopack_target_ms = float(
                os.environ.get("REPRO_RUNNER_PACK_TARGET_MS", "50")
            )
        self._autopack = (
            _AutoPack(autopack_target_ms / 1e3, hi=max(64, pack))
            if autopack
            else None
        )
        # concurrent dispatches time each other's compute; only SOLO
        # measurements (no other superbatch in flight for the whole span)
        # feed the autopack controller
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._fn = plan.jit_for(engine=engine, donate=donate)
        # pinned staging slots: signature -> list of {col: np.ndarray}
        self._slots: dict = {}
        self.stats = {
            "batches_in": 0,
            "superbatches": 0,
            "rows": 0,
            "seconds": 0.0,
        }

    # -- staging -----------------------------------------------------------

    def _stage(self, group: List[T.Batch], slot_idx: int) -> T.Batch:
        """Pack a group of host batches and place it on device.  Numpy
        columns concatenate/copy directly into a reused staging slot (one
        copy, no steady-state allocation); device-resident columns
        concatenate on device."""
        if self._required is not None:
            group = [
                {k: v for k, v in b.items() if k in self._required} for b in group
            ]
        slot = self._slot_for(group, slot_idx) if self.staging else None
        host: T.Batch = {}
        for k in group[0]:
            vals = [b[k] for b in group]
            if not all(isinstance(v, np.ndarray) for v in vals):
                import jax.numpy as jnp

                if len(vals) > 1:
                    host[k] = jnp.concatenate([jnp.asarray(v) for v in vals], axis=0)
                elif self.donate and isinstance(vals[0], jax.Array):
                    # a lone device array would pass through device_put
                    # unchanged — donation would invalidate the CALLER's
                    # buffer, so take a private copy first
                    host[k] = jnp.copy(vals[0])
                else:
                    host[k] = vals[0]
            elif slot is not None:
                if len(vals) == 1:
                    np.copyto(slot[k], vals[0])
                else:
                    np.concatenate(vals, axis=0, out=slot[k])
                host[k] = slot[k]
            else:
                host[k] = np.concatenate(vals, axis=0) if len(vals) > 1 else vals[0]
        return stage_batch(host, self._sharding)

    def _slot_for(self, group: List[T.Batch], slot_idx: int):
        """Pinned numpy buffers for this group's packed signature, or None
        when the group has no numpy columns."""
        np_cols = {
            k: v for k, v in group[0].items() if isinstance(v, np.ndarray)
        }
        if not np_cols:
            return None
        n_rows = sum(int(next(iter(b.values())).shape[0]) for b in group)
        sig = tuple(
            (k, (n_rows,) + v.shape[1:], str(v.dtype))
            for k, v in sorted(np_cols.items())
        )
        slots = self._slots.setdefault(sig, {})
        slot = slots.get(slot_idx)
        if slot is None:
            slot = {
                k: np.empty((n_rows,) + v.shape[1:], v.dtype)
                for k, v in np_cols.items()
            }
            slots[slot_idx] = slot
        return slot

    def _staged(self, batches: Iterable[T.Batch]) -> Iterator[Tuple[T.Batch, List[int]]]:
        """Yield (device superbatch, per-batch row counts).

        Groups only equal-signature batches; a signature change or iterator
        end flushes the current group (possibly under-full — it still runs
        through the same plan, just as its own executable signature)."""
        group: List[T.Batch] = []
        group_sig = None
        slot_idx = 0
        # staging-queue depth + in-flight compute window + the one being
        # staged: a slot is never rewritten while its bytes may still be in
        # use (on CPU device_put copies synchronously, so any count works)
        n_slots = 2 * self.prefetch + self.workers + 2

        def flush():
            nonlocal group, slot_idx
            rows = [int(next(iter(b.values())).shape[0]) for b in group]
            staged = self._stage(group, slot_idx % n_slots)
            slot_idx += 1
            group = []
            return staged, rows

        for b in batches:
            # shape/dtype only — never np.asarray, which would drag a
            # device-resident column to host just to read metadata
            sig = tuple(
                (k, np.shape(v)[1:], str(v.dtype)) for k, v in sorted(b.items())
            )
            rows0 = np.shape(next(iter(b.values())))[0]
            sig = (rows0, sig)
            if group and (sig != group_sig or len(group) >= self.pack):
                yield flush()
            group_sig = sig
            group.append(b)
        if group:
            yield flush()

    # -- execution ---------------------------------------------------------

    def run(self, batches: Iterable[T.Batch]) -> Iterator[T.Batch]:
        """Transform every batch; yields one output batch per input batch,
        in order, batch-for-batch identical to ``fitted.transform``."""
        from repro.data.pipeline import prefetch as _prefetch

        t0 = time.perf_counter()
        staged = self._staged(batches)
        if self.prefetch > 0:
            staged = _prefetch(staged, depth=self.prefetch)

        try:
            if self.workers > 1:
                yield from self._run_workers(staged)
            else:
                yield from self._run_serial(staged)
        finally:
            self.stats["seconds"] += time.perf_counter() - t0

    def _account(self, rows: List[int]) -> None:
        self.stats["superbatches"] += 1
        self.stats["batches_in"] += len(rows)
        self.stats["rows"] += sum(rows)

    def _dispatch(self, dev: T.Batch, rows: List[int]) -> T.Batch:
        """One plan call.  While autopack is active the call is synchronous
        and timed, and ``self.pack`` follows the controller — the staging
        generator reads ``self.pack`` per group, so adjustments shape the
        superbatches formed after this one.  Once settled (or with autopack
        off) dispatch is fully asynchronous again."""
        ap = self._autopack
        if ap is None or ap.settled:
            return self._fn(dev)
        with self._inflight_lock:
            self._inflight += 1
            solo = self._inflight == 1
        try:
            t0 = self._clock()
            out = self._fn(dev)
            jax.block_until_ready(out)
            dt = self._clock() - t0
        finally:
            with self._inflight_lock:
                solo = solo and self._inflight == 1
                self._inflight -= 1
        if solo:  # overlapped measurements read ~workers x the true cost
            self.pack = ap.observe(len(rows), self.pack, dt)
        return out

    def _run_serial(self, staged) -> Iterator[T.Batch]:
        inflight: collections.deque = collections.deque()
        for dev, rows in staged:
            out = self._dispatch(dev, rows)
            inflight.append((out, rows))
            self._account(rows)
            if len(inflight) > self.prefetch:
                yield from self._emit(*inflight.popleft())
        while inflight:
            yield from self._emit(*inflight.popleft())

    def _run_workers(self, staged) -> Iterator[T.Batch]:
        """Dispatch superbatches from ``workers`` threads so independent XLA
        executions overlap across host cores; results re-emit in order."""
        import concurrent.futures as cf

        def one(dev, rows):
            out = self._dispatch(dev, rows)
            jax.block_until_ready(out)
            return out, rows

        window = self.workers + self.prefetch
        with cf.ThreadPoolExecutor(max_workers=self.workers) as pool:
            futs: collections.deque = collections.deque()
            for dev, rows in staged:
                futs.append(pool.submit(one, dev, rows))
                self._account(rows)
                if len(futs) >= window:
                    yield from self._emit(*futs.popleft().result())
            while futs:
                yield from self._emit(*futs.popleft().result())

    def _emit(self, out: T.Batch, rows: List[int]) -> Iterator[T.Batch]:
        jax.block_until_ready(out)
        if self.materialize == "host":
            out = {k: np.asarray(v) for k, v in out.items()}
        if len(rows) == 1:
            yield out
            return
        off = 0
        for r in rows:
            # on host these are zero-copy numpy views; on device, slice ops
            yield {k: v[off : off + r] for k, v in out.items()}
            off += r

    def run_collect(self, batches: Iterable[T.Batch]) -> List[T.Batch]:
        """Materialise the whole stream (small epochs / tests)."""
        return list(self.run(batches))

    @property
    def rows_per_s(self) -> float:
        return self.stats["rows"] / max(self.stats["seconds"], 1e-9)

    def __repr__(self) -> str:
        sh = "sharded" if self._sharding is not None else "single-device"
        ap = ""
        if self._autopack is not None:
            state = "settled" if self._autopack.settled else "adapting"
            ap = f", autopack={state}({self._autopack.adjustments} adj)"
        return (
            f"PlanRunner({sh}, pack={self.pack}, prefetch={self.prefetch}, "
            f"donate={self.donate}, rows={self.stats['rows']}{ap})"
        )
