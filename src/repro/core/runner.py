"""PlanRunner — streaming, sharding-aware executor for TransformPlans.

The Spark-role offline transform ("apply the fitted pipeline to an epoch of
data") is the throughput path of the paper's bridge, and prior measurement
shows the input pipeline — not the kernels — dominates tabular preprocessing
cost once the per-batch graph is compiled.  ``FittedPipeline.transform_jit``
in a loop leaves three kinds of time on the floor:

  1. every batch blocks: host staging, device dispatch and result readback
     serialise instead of overlapping;
  2. every batch pays the full per-call fixed cost (host→device transfer
     setup, dispatch, output allocation) at whatever batch size the data
     lake handed us;
  3. the compiled executable is blind to meshes, so the offline sweep cannot
     reuse the serving path's plan (or vice versa).

``PlanRunner`` drives an entire batch iterator through ONE cached executable
of a :class:`~repro.core.plan.TransformPlan`:

* **Packing** — up to ``pack`` equal-shaped batches are concatenated on the
  host into one superbatch, amortising per-call fixed cost and giving XLA
  wider arrays (all pipeline stages are row-wise, so results are
  batch-for-batch identical — asserted by tests).  Leftover batches that
  don't fill a pack run through the same plan individually.
* **Double-buffered host→device staging** — packing + ``jax.device_put``
  run in a background thread ``prefetch`` superbatches ahead of compute, so
  host staging overlaps device execution.  With an ``engine`` (mesh), the
  device_put places each column with ``Engine.batch_sharding()`` and the
  executable is lowered with matching ``in_shardings`` — the pod-sharded
  offline sweep and the single-device serve path share one plan.
* **Donation** — staged input buffers are donated to the executable by
  default (they are private to the runner), letting XLA reuse them for
  outputs instead of allocating per batch.
* **Pinned staging** (optional, CPU default) — numpy columns concatenate
  directly into preallocated staging arrays before device_put, so
  steady-state streaming does no host allocation.  Slots cycle beyond the
  in-flight window; on CPU (the default-enabled backend) device_put copies
  synchronously, so a slot is always free by the time it cycles back.

The same staging helper (:func:`stage_batch`) backs the online
``MicroBatcher``, keeping offline and serving host→device handling unified.

**Multi-host shard feeding.**  With a
:class:`~repro.launch.mesh.ProcessMesh`, every process of a multi-process
job drives the SAME logical batch stream, but each stages only its
addressable rows of every superbatch:

* ``shard_mode="global"`` — the process device_puts its row block per
  addressable data shard and assembles the globally-sharded superbatch with
  ``jax.make_array_from_single_device_arrays``; the executable is lowered
  with the global batch sharding (SPMD: every process runs the same
  program).  This is the TPU-pod path; it also runs single-process over a
  virtual topology (all shards addressable), which is how tests cover it.
* ``shard_mode="local"`` — the process computes ONLY its row block, on a
  mesh over its own devices.  Row-wise plans need no cross-shard
  collectives, so concatenating the per-process outputs in process order is
  bit-identical to the single-process result (asserted by the differential
  tests in ``tests/test_multihost.py``).  This is the default off-TPU,
  where XLA cannot execute cross-process programs.

Donation and pinned staging work unchanged in both modes (slots are sized
to the local block, so steady-state staging still does no host allocation),
and ``materialize="host"`` yields this process's rows as numpy views.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.obs import envknobs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.transport.frames import ascontiguous

from . import types as T


def gather_addressable(v):
    """Host numpy copy of a value's ADDRESSABLE rows: the whole array when
    fully addressable (or not a jax array), else this process's addressable
    row block — per-shard data concatenated in row order.  ``np.asarray``
    on a multi-process global array raises; this is the multi-host-safe
    spelling the host-materialising paths use."""
    if isinstance(v, jax.Array) and not v.is_fully_addressable:
        shards = sorted(
            v.addressable_shards,
            key=lambda s: s.index[0].start if s.index and s.index[0].start else 0,
        )
        return np.concatenate([np.asarray(s.data) for s in shards], axis=0)
    return np.asarray(v)


def stage_batch(batch, sharding=None):
    """Place one host batch on device, sharded when ``sharding`` is given.

    Shared by the offline PlanRunner and the online MicroBatcher so both
    paths stage identically (and a mesh-sharded serving tier needs only a
    sharding argument)."""
    if sharding is None:
        return {k: jax.device_put(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


def _autopack_default() -> bool:
    return envknobs.env_flag("REPRO_RUNNER_AUTOPACK", False)


class _AutoPack:
    """Halve/double ``pack`` toward a per-superbatch latency target.

    The fixed ``pack=8`` default sits on a cache cliff for some hosts (see
    ROADMAP): too large a superbatch blows the cache and adds latency, too
    small leaves per-call fixed cost unamortised.  This controller measures
    superbatch wall time and walks ``pack`` toward ``target`` seconds per
    call: above the target it halves, below half the target it doubles, and
    inside the band (or at a bound) it settles — after which measurement
    stops and the runner returns to fully-async dispatch.

    The first measured superbatch is discarded: it pays compile cost and
    would otherwise always read as "too slow".  Leftover groups smaller than
    the current pack are ignored — they are not representative of a full
    superbatch.  ``observe`` is thread-safe (worker dispatch threads)."""

    def __init__(self, target_s: float, lo: int = 1, hi: int = 64):
        self.target = float(target_s)
        self.lo = int(lo)
        self.hi = int(hi)
        self.warmed = False
        self.settled = False
        self.adjustments = 0
        self._lock = threading.Lock()

    def observe(self, pack_used: int, current_pack: int, seconds: float) -> int:
        with self._lock:
            if self.settled:
                return current_pack
            if not self.warmed:
                self.warmed = True  # compile superbatch: never representative
                return current_pack
            if pack_used < current_pack:
                return current_pack  # under-full leftover group
            if seconds > self.target:
                new = max(self.lo, current_pack // 2)
            elif seconds < self.target / 2:
                new = min(self.hi, current_pack * 2)
            else:
                new = current_pack
            if new == current_pack:
                self.settled = True
            else:
                self.adjustments += 1
            return new


class PlanRunner:
    """Stream an entire batch iterator through one compiled TransformPlan.

    Args:
      plan: a :class:`~repro.core.plan.TransformPlan` (typically
        ``fitted.plan()`` or ``model.plan()``).
      engine: optional :class:`~repro.core.engine.Engine`; with a mesh, input
        columns are device_put with ``batch_sharding()`` and the executable
        is lowered with matching ``in_shardings``.
      donate: donate staged input buffers to the executable (default True —
        the staged superbatch is private to the runner).
      pack: number of equal-shaped input batches fused into one executable
        call.  1 disables packing.
      prefetch: how many staged superbatches the background staging thread
        keeps ahead of compute (double buffering at the default 2).
      staging: reuse pinned host staging arrays for numpy inputs.  None =
        auto (enabled on the CPU backend, where device_put copies
        synchronously and slot reuse is trivially safe).
      workers: concurrent compute dispatch streams.  None = auto (2 on the
        CPU backend, where XLA executions from distinct host threads run
        concurrently across cores; 1 elsewhere — an accelerator serializes
        compute on-device, so extra dispatch threads only add contention).
        Output order is preserved regardless.
      autopack: adapt ``pack`` at runtime from measured superbatch wall time
        (halve above ``autopack_target_ms``, double below half of it, settle
        in between — see :class:`_AutoPack`).  None = the
        ``REPRO_RUNNER_AUTOPACK=1`` env default (off).
      autopack_target_ms: target superbatch latency for autopack.  None =
        the ``REPRO_RUNNER_PACK_TARGET_MS`` env default (50 ms).
      clock: monotonic time source for autopack measurement (tests inject a
        fake clock; production uses ``time.perf_counter``).
      materialize: where yielded batches live.  "device" (default) yields
        device arrays (sliced per input batch when packed — each slice is a
        device op).  "host" transfers each computed superbatch to the host
        once and yields zero-copy numpy views per batch — the right mode for
        an offline sweep that writes results out, and much cheaper than
        per-batch device slicing when packing.
      process_mesh: a :class:`~repro.launch.mesh.ProcessMesh` for multi-host
        execution — every process drives the same logical stream, stages
        only its addressable rows of each superbatch, and (in "local" shard
        mode) yields only its row block per input batch.  Mutually exclusive
        with ``engine``.
      shard_mode: "global" (assemble globally-sharded superbatches, run the
        SPMD executable on the global mesh), "local" (compute only this
        process's row block on its own devices — exact for row-wise plans),
        or None/"auto": "global" when the runtime can execute it (single
        process with a virtual topology, or a non-CPU backend), else
        "local" (XLA CPU cannot run cross-process programs).
    """

    def __init__(
        self,
        plan,
        engine=None,
        donate: bool = True,
        pack: int = 8,
        prefetch: int = 2,
        staging: Optional[bool] = None,
        workers: Optional[int] = None,
        materialize: str = "device",
        autopack: Optional[bool] = None,
        autopack_target_ms: Optional[float] = None,
        clock: Optional[Callable[[], float]] = None,
        process_mesh=None,
        shard_mode: Optional[str] = None,
    ):
        if materialize not in ("device", "host"):
            raise ValueError("materialize must be 'device' or 'host'")
        self.materialize = materialize
        if pack < 1:
            raise ValueError("pack must be >= 1")
        self.plan = plan
        self.engine = engine
        self.donate = donate
        self.pack = pack
        self.prefetch = max(int(prefetch), 0)
        if staging is None:
            staging = jax.default_backend() == "cpu"
        self.staging = staging
        if workers is None:
            workers = 2 if jax.default_backend() == "cpu" else 1
        self.workers = max(int(workers), 1)
        if process_mesh is not None and engine is not None:
            raise ValueError("pass either engine= or process_mesh=, not both")
        self.process_mesh = process_mesh
        if shard_mode not in (None, "auto", "local", "global"):
            raise ValueError(f"unknown shard_mode {shard_mode!r}")
        if process_mesh is not None and shard_mode in (None, "auto"):
            # global execution needs a runtime that can actually run the
            # SPMD program: one process addressing the whole (virtual) mesh,
            # or a backend with cross-process execution (TPU).  XLA CPU
            # multi-process falls back to exact local-block execution.
            can_global = process_mesh.global_mesh is not None and (
                jax.process_count() == 1 or jax.default_backend() != "cpu"
            )
            shard_mode = "global" if can_global else "local"
        self.shard_mode = shard_mode if process_mesh is not None else None
        if process_mesh is not None:
            if self.shard_mode == "global":
                self._sharding = process_mesh.global_batch_sharding()
            else:
                self._sharding = process_mesh.local_batch_sharding()
        else:
            self._sharding = (
                engine.batch_sharding()
                if engine is not None and engine.mesh is not None
                else None
            )
        # outputs-constrained plans declare which raw columns they read; the
        # runner stages only those (the rest never cross host->device)
        req = getattr(plan, "required_inputs", lambda: None)()
        self._required = set(req) if req is not None else None
        self._clock = clock if clock is not None else time.perf_counter
        if autopack is None:
            autopack = _autopack_default()
        if autopack_target_ms is None:
            autopack_target_ms = envknobs.env_float(
                "REPRO_RUNNER_PACK_TARGET_MS", 50.0
            )
        self._autopack = (
            _AutoPack(autopack_target_ms / 1e3, hi=max(64, pack))
            if autopack
            else None
        )
        # concurrent dispatches time each other's compute; only SOLO
        # measurements (no other superbatch in flight for the whole span)
        # feed the autopack controller
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        if process_mesh is not None:
            self._fn = plan.jit_for(in_shardings=self._sharding, donate=donate)
        else:
            self._fn = plan.jit_for(engine=engine, donate=donate)
        # pinned staging slots: signature -> list of {col: np.ndarray}
        self._slots: dict = {}
        self._fused_warmed = False
        self.stats = {
            "batches_in": 0,
            "superbatches": 0,
            "rows": 0,
            "local_rows": 0,
            "seconds": 0.0,
            "fused_chains": getattr(plan, "fused_chain_count", 0),
        }
        # per-run sweep root span: staging runs in the prefetch background
        # thread, so children parent to this explicitly (the thread-local
        # parent stack cannot cross that boundary)
        self._obs_root = None
        obs_metrics.get_registry().register_source("runner", self.obs_snapshot)

    def obs_snapshot(self) -> dict:
        """Throughput counters for the metrics registry (weakly held — a
        collected runner drops out of ``obs.snapshot()`` on its own)."""
        return dict(self.stats)

    # -- staging -----------------------------------------------------------

    def _geometry(self, n: int) -> Tuple[int, int, int, int]:
        """Staging geometry of an ``n``-row superbatch: ``(s, e, store,
        n_global)`` — this process stages superbatch rows ``[s, min(e, n))``
        into a ``store``-row block (zero rows beyond the real data pad the
        block to shard divisibility — row-wise plans make them inert and
        emission never yields them), and the assembled/global row count is
        ``n_global``.  Without a process mesh: the whole superbatch."""
        pm = self.process_mesh
        if pm is None:
            return 0, n, n, n
        if self.shard_mode == "global":
            # jax can only assemble evenly-sharded global arrays: pad the
            # LOGICAL batch to shard divisibility, identically on every
            # process (the pad rows land on the trailing shards)
            n_global = n + (-n) % pm.num_data_shards
            s, e = pm.addressable_row_block(n_global)
            return s, e, e - s, n_global
        s, e = pm.row_block(n)
        lshards = pm.my_shards[1] - pm.my_shards[0]
        store = (e - s) + (-(e - s)) % lshards
        return s, e, store, n

    def _stage(self, group: List[T.Batch], slot_idx: int) -> T.Batch:
        """Pack a group of host batches and place it on device.  Numpy
        columns concatenate/copy directly into a reused staging slot (one
        copy, no steady-state allocation); device-resident columns
        concatenate on device.  With a process mesh, only this process's
        row block of the packed superbatch crosses host→device — the slot
        is sized to the block, and each input batch contributes its
        intersection with the block."""
        if self._required is not None:
            group = [
                {k: v for k, v in b.items() if k in self._required} for b in group
            ]
        rows = [int(np.shape(next(iter(b.values())))[0]) for b in group]
        n = sum(rows)
        s, e, store, n_global = self._geometry(n)
        # clamp DOWN to s as well: a process whose global-mode block lies
        # entirely in the divisibility-pad region (n < its first row) stages
        # pure zero padding — e_real < s would corrupt the pad arithmetic
        e_real = max(min(e, n), s)
        # (batch index, src slice into the batch, dst offset in the block)
        pieces: List[Tuple[int, slice, int]] = []
        off = 0
        for i, r in enumerate(rows):
            a = min(max(off, s), e_real)
            b = min(max(off + r, s), e_real)
            if b > a:
                pieces.append((i, slice(a - off, b - off), a - s))
            off += r
        fill = e_real - s  # real rows staged; [fill, store) is zero padding
        slot = self._slot_for(group, slot_idx, store) if self.staging else None
        host: T.Batch = {}
        for k in group[0]:
            vals = [group[i][k][sl] for i, sl, _ in pieces]
            if not all(isinstance(v, np.ndarray) for v in vals):
                import jax.numpy as jnp

                if fill < store:
                    pad = jnp.zeros(
                        (store - fill,) + tuple(np.shape(group[0][k]))[1:],
                        group[0][k].dtype,
                    )
                    vals = [jnp.asarray(v) for v in vals] + [pad]
                if len(vals) > 1:
                    host[k] = jnp.concatenate([jnp.asarray(v) for v in vals], axis=0)
                elif not vals:  # empty block (store == 0): 0-row column
                    host[k] = jnp.asarray(group[0][k])[0:0]
                elif self.donate and isinstance(vals[0], jax.Array) and (s, e) == (0, n):
                    # a lone device array would pass through device_put
                    # unchanged — donation would invalidate the CALLER's
                    # buffer, so take a private copy first (a block slice is
                    # already a fresh buffer)
                    host[k] = jnp.copy(vals[0])
                else:
                    host[k] = vals[0]
            elif slot is not None:
                for v, (_, _, dst) in zip(vals, pieces):
                    slot[k][dst : dst + v.shape[0]] = v
                if fill < store:
                    slot[k][fill:store] = 0  # slots are reused: re-zero the pad
                host[k] = slot[k]
            else:
                if fill < store:
                    vals = vals + [
                        np.zeros(
                            (store - fill,) + np.shape(group[0][k])[1:],
                            group[0][k].dtype,
                        )
                    ]
                host[k] = (
                    np.concatenate(vals, axis=0)
                    if len(vals) > 1
                    else (
                        # the single-piece fast path hands the batch's row
                        # slice through as a VIEW; normalise it to
                        # C-contiguous here (identity when already so) so a
                        # downstream multi-host dispatch never serialises a
                        # strided column — plan-stream shard feeding and the
                        # gateway see one layout
                        ascontiguous(vals[0])
                        if vals
                        else np.asarray(group[0][k])[0:0]  # empty block
                    )
                )
        self.stats["local_rows"] += fill
        if self.process_mesh is not None and self.shard_mode == "global":
            return self.process_mesh.stage_global(host, n_global)
        return stage_batch(host, self._sharding)

    def _slot_for(self, group: List[T.Batch], slot_idx: int, n_rows: int):
        """Pinned numpy buffers for this group's packed signature (``n_rows``
        = the rows this process stages), or None when the group has no numpy
        columns."""
        np_cols = {
            k: v for k, v in group[0].items() if isinstance(v, np.ndarray)
        }
        if not np_cols:
            return None
        sig = tuple(
            (k, (n_rows,) + v.shape[1:], str(v.dtype))
            for k, v in sorted(np_cols.items())
        )
        slots = self._slots.setdefault(sig, {})
        slot = slots.get(slot_idx)
        if slot is None:
            slot = {
                k: np.empty((n_rows,) + v.shape[1:], v.dtype)
                for k, v in np_cols.items()
            }
            slots[slot_idx] = slot
        return slot

    def _staged(self, batches: Iterable[T.Batch]) -> Iterator[Tuple[T.Batch, List[int]]]:
        """Yield (device superbatch, per-batch row counts).

        Groups only equal-signature batches; a signature change or iterator
        end flushes the current group (possibly under-full — it still runs
        through the same plan, just as its own executable signature)."""
        group: List[T.Batch] = []
        group_sig = None
        slot_idx = 0
        # staging-queue depth + in-flight compute window + the one being
        # staged: a slot is never rewritten while its bytes may still be in
        # use (on CPU device_put copies synchronously, so any count works)
        n_slots = 2 * self.prefetch + self.workers + 2

        def flush():
            nonlocal group, slot_idx
            rows = [int(next(iter(b.values())).shape[0]) for b in group]
            root = self._obs_root
            with obs_trace.get_recorder().span(
                "runner.stage", component="runner",
                parent=root if root is not None else obs_trace.NULL,
                attrs={"batches": len(group), "rows": sum(rows)},
            ):
                staged = self._stage(group, slot_idx % n_slots)
            slot_idx += 1
            group = []
            # multihost emission spans: in local shard mode outputs cover
            # only this process's row block (each input batch yields its
            # intersection); in global mode the assembled output may carry
            # divisibility padding, which the span clips off
            span = None
            if self.process_mesh is not None:
                n = sum(rows)
                span = (
                    (0, n)
                    if self.shard_mode == "global"
                    else self.process_mesh.row_block(n)
                )
            return staged, rows, span

        for b in batches:
            # shape/dtype only — never np.asarray, which would drag a
            # device-resident column to host just to read metadata
            sig = tuple(
                (k, np.shape(v)[1:], str(v.dtype)) for k, v in sorted(b.items())
            )
            rows0 = np.shape(next(iter(b.values())))[0]
            sig = (rows0, sig)
            if group and (sig != group_sig or len(group) >= self.pack):
                yield flush()
            group_sig = sig
            group.append(b)
        if group:
            yield flush()

    # -- execution ---------------------------------------------------------

    def run(self, batches: Iterable[T.Batch]) -> Iterator[T.Batch]:
        """Transform every batch; yields one output batch per input batch,
        in order, batch-for-batch identical to ``fitted.transform``."""
        from repro.data.pipeline import prefetch as _prefetch

        t0 = time.perf_counter()
        self._obs_root = obs_trace.get_recorder().root_span(
            "runner.sweep", component="runner",
            attrs={"pack": self.pack, "workers": self.workers,
                   "shard_mode": self.shard_mode or "none"},
        )
        staged = self._staged(self._fused_warmup(batches))
        if self.prefetch > 0:
            staged = _prefetch(staged, depth=self.prefetch)

        try:
            if self.workers > 1:
                yield from self._run_workers(staged)
            else:
                yield from self._run_serial(staged)
        finally:
            self.stats["seconds"] += time.perf_counter() - t0
            root, self._obs_root = self._obs_root, None
            root.set("rows", self.stats["rows"])
            root.set("superbatches", self.stats["superbatches"])
            root.end()

    def _fused_warmup(self, batches: Iterable[T.Batch]) -> Iterator[T.Batch]:
        """Autotune the plan's fused chains on the FIRST host batch of the
        stream (once per runner), so the superbatch executable compiled right
        after lowers with tuned block configs — a persisted-cache hit costs
        one store lookup and zero sweeps.  No-op when the plan has no fused
        nodes or the kernel route is off (then ``warm_fused`` returns the
        tuner stats without executing anything)."""
        it = iter(batches)
        first = next(it, None)
        if first is None:
            return
        if not self._fused_warmed:
            self._fused_warmed = True
            warm = getattr(self.plan, "warm_fused", None)  # stub plans lack it
            if warm is not None:
                self.stats["fused_tune"] = warm(first)
        yield first
        yield from it

    def _account(self, rows: List[int]) -> None:
        self.stats["superbatches"] += 1
        self.stats["batches_in"] += len(rows)
        self.stats["rows"] += sum(rows)

    def _dispatch(self, dev: T.Batch, rows: List[int]) -> T.Batch:
        """One plan call.  While autopack is active the call is synchronous
        and timed, and ``self.pack`` follows the controller — the staging
        generator reads ``self.pack`` per group, so adjustments shape the
        superbatches formed after this one.  Once settled (or with autopack
        off) dispatch is fully asynchronous again."""
        ap = self._autopack
        root = self._obs_root
        sp = obs_trace.get_recorder().span(
            "runner.dispatch", component="runner",
            parent=root if root is not None else obs_trace.NULL,
            attrs={"batches": len(rows), "rows": sum(rows)},
        )
        if ap is None or ap.settled:
            with sp:
                return self._fn(dev)
        with self._inflight_lock:
            self._inflight += 1
            solo = self._inflight == 1
        try:
            t0 = self._clock()
            with sp:
                out = self._fn(dev)
                jax.block_until_ready(out)
            dt = self._clock() - t0
        finally:
            with self._inflight_lock:
                solo = solo and self._inflight == 1
                self._inflight -= 1
        if solo:  # overlapped measurements read ~workers x the true cost
            self.pack = ap.observe(len(rows), self.pack, dt)
        return out

    def _run_serial(self, staged) -> Iterator[T.Batch]:
        inflight: collections.deque = collections.deque()
        for dev, rows, span in staged:
            out = self._dispatch(dev, rows)
            inflight.append((out, rows, span))
            self._account(rows)
            if len(inflight) > self.prefetch:
                yield from self._emit(*inflight.popleft())
        while inflight:
            yield from self._emit(*inflight.popleft())

    def _run_workers(self, staged) -> Iterator[T.Batch]:
        """Dispatch superbatches from ``workers`` threads so independent XLA
        executions overlap across host cores; results re-emit in order."""
        import concurrent.futures as cf

        def one(dev, rows):
            out = self._dispatch(dev, rows)
            jax.block_until_ready(out)
            return out, rows

        window = self.workers + self.prefetch
        with cf.ThreadPoolExecutor(max_workers=self.workers) as pool:
            futs: collections.deque = collections.deque()
            for dev, rows, span in staged:
                futs.append((pool.submit(one, dev, rows), span))
                self._account(rows)
                if len(futs) >= window:
                    fut, sp = futs.popleft()
                    yield from self._emit(*fut.result(), sp)
            while futs:
                fut, sp = futs.popleft()
                yield from self._emit(*fut.result(), sp)

    def _emit(
        self, out: T.Batch, rows: List[int], span: Optional[Tuple[int, int]] = None
    ) -> Iterator[T.Batch]:
        jax.block_until_ready(out)
        if self.materialize == "host":
            partial = any(
                isinstance(v, jax.Array) and not v.is_fully_addressable
                for v in out.values()
            )
            out = {k: gather_addressable(v) for k, v in out.items()}
            if partial and self.shard_mode == "global":
                # real multi-process runtime: the host copy holds only this
                # process's addressable row block, so emit per-batch
                # intersections exactly as local mode does (the block's
                # trailing divisibility padding falls outside the span)
                n = sum(rows)
                n_global = n + (-n) % self.process_mesh.num_data_shards
                s, e = self.process_mesh.addressable_row_block(n_global)
                span = (s, max(min(e, n), s))
        if span is not None:
            # local shard mode: ``out`` covers rows [s, e) of the logical
            # superbatch; every input batch yields its intersection (possibly
            # zero rows — the batch belongs to another process entirely)
            s, e = span
            off = 0
            for r in rows:
                a = min(max(off, s), e) - s
                b = min(max(off + r, s), e) - s
                yield {k: v[a:b] for k, v in out.items()}
                off += r
            return
        if len(rows) == 1:
            yield out
            return
        off = 0
        for r in rows:
            # on host these are zero-copy numpy views; on device, slice ops
            yield {k: v[off : off + r] for k, v in out.items()}
            off += r

    def run_collect(self, batches: Iterable[T.Batch]) -> List[T.Batch]:
        """Materialise the whole stream (small epochs / tests)."""
        return list(self.run(batches))

    @property
    def rows_per_s(self) -> float:
        return self.stats["rows"] / max(self.stats["seconds"], 1e-9)

    def __repr__(self) -> str:
        sh = "sharded" if self._sharding is not None else "single-device"
        if self.process_mesh is not None:
            sh = f"multihost[{self.shard_mode}] {self.process_mesh!r}"
        ap = ""
        if self._autopack is not None:
            state = "settled" if self._autopack.settled else "adapting"
            ap = f", autopack={state}({self._autopack.adjustments} adj)"
        return (
            f"PlanRunner({sh}, pack={self.pack}, prefetch={self.prefetch}, "
            f"donate={self.donate}, rows={self.stats['rows']}{ap})"
        )
