"""Pure-jnp string primitives over uint8 byte tensors.

Everything here is built from native XLA ops (no host callbacks) — the JAX
analogue of the paper's "native transformations rather than user-defined
functions" design rule, which is what lets the compiler (Catalyst there, XLA
here) fuse and optimise preprocessing.

Shapes: a string tensor is ``(..., L)`` uint8 with trailing zero padding.
All functions are rank-polymorphic over the leading dims.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from . import types as T

_ZERO = jnp.uint8(0)


# ---------------------------------------------------------------------------
# numeric <-> string
# ---------------------------------------------------------------------------

def number_to_string(values: jax.Array, max_len: int = T.DEFAULT_MAX_LEN) -> jax.Array:
    """Decimal string (uint8 tensor) of an integer column.

    Floats are not supported in-graph (no exact decimal repr on TPU);
    cast/round on the host side of the pipeline instead.
    """
    if not jnp.issubdtype(values.dtype, jnp.integer) and not jnp.issubdtype(
        values.dtype, jnp.bool_
    ):
        raise TypeError(f"number_to_string requires integer input, got {values.dtype}")
    v = values.astype(jnp.int64)
    neg = v < 0
    mag = jnp.where(neg, -v, v).astype(jnp.uint64)

    ndig = 20  # max digits of uint64
    pows = jnp.asarray([10 ** (ndig - 1 - i) for i in range(ndig)], jnp.uint64)
    digits = (mag[..., None] // pows) % jnp.uint64(10)  # (..., 20) most-significant first
    nonzero = digits > 0
    any_nz = jnp.any(nonzero, axis=-1)
    lead = jnp.argmax(nonzero, axis=-1)  # first significant digit
    lead = jnp.where(any_nz, lead, ndig - 1)  # value 0 -> single '0'
    ndigits = ndig - lead

    out_len = max_len
    k = jnp.arange(out_len)
    sign_off = neg.astype(jnp.int64)
    # out[k] = '-' at k=0 if negative; digit (lead + k - sign_off) otherwise
    src = lead[..., None] + k - sign_off[..., None]
    src_c = jnp.clip(src, 0, ndig - 1)
    dig = jnp.take_along_axis(digits, src_c.astype(jnp.int64), axis=-1)
    ch = (dig + jnp.uint64(ord("0"))).astype(jnp.uint8)
    valid = (src >= lead[..., None]) & (src < ndig)
    out = jnp.where(valid, ch, _ZERO)
    minus = (k == 0) & neg[..., None]
    out = jnp.where(minus, jnp.uint8(ord("-")), out)
    return out


def string_to_number(strings: jax.Array, dtype: str = "float32") -> jax.Array:
    """Parse decimal strings (optional sign, optional fraction) to numbers.

    Unparseable strings yield NaN for float dtypes and 0 for int dtypes.
    Exponent notation is not supported (documented limitation).

    The per-byte parser state advances via ``lax.scan`` over the byte axis:
    step ops match the historical unrolled loop exactly (bit-exact results,
    asserted by tests) while the traced program is O(1) in ``max_len``.
    """
    s = strings.astype(jnp.int32)
    L = strings.shape[-1]
    shape = strings.shape[:-1]

    init = (
        jnp.zeros(shape, jnp.float64),  # val
        jnp.ones(shape, jnp.float64),   # scale: 10^-k after k-th fraction digit
        jnp.zeros(shape, bool),         # seen_dot
        jnp.zeros(shape, bool),         # seen_digit
        jnp.zeros(shape, bool),         # invalid
        jnp.zeros(shape, bool),         # neg
    )

    def step(carry, xs):
        val, scale, seen_dot, seen_digit, invalid, neg = carry
        c, i = xs
        is_nul = c == 0
        is_digit = (c >= 48) & (c <= 57)
        is_dot = c == 46
        is_sign = ((c == 43) | (c == 45)) & (i == 0)
        d = (c - 48).astype(jnp.float64)
        val = jnp.where(is_digit & ~seen_dot, val * 10.0 + d, val)
        scale = jnp.where(is_digit & seen_dot, scale * 0.1, scale)
        val = jnp.where(is_digit & seen_dot, val + d * scale, val)
        seen_digit = seen_digit | is_digit
        invalid = invalid | ~(is_nul | is_digit | is_dot | is_sign) | (is_dot & seen_dot)
        seen_dot = seen_dot | is_dot
        neg = jnp.where(is_sign & (c == 45), True, neg)
        return (val, scale, seen_dot, seen_digit, invalid, neg), None

    xs = (jnp.moveaxis(s, -1, 0), jnp.arange(L, dtype=jnp.int32))
    (val, _, _, seen_digit, invalid, neg), _ = jax.lax.scan(step, init, xs)
    invalid = invalid | ~seen_digit
    out = jnp.where(neg, -val, val)
    jdt = jnp.dtype(dtype)
    if jnp.issubdtype(jdt, jnp.floating):
        out = jnp.where(invalid, jnp.nan, out)
        return out.astype(jdt)
    return jnp.where(invalid, 0, out).astype(jdt)


# ---------------------------------------------------------------------------
# case / trim / slice
# ---------------------------------------------------------------------------

def upper(strings: jax.Array) -> jax.Array:
    is_lower = (strings >= 97) & (strings <= 122)
    return jnp.where(is_lower, strings - 32, strings)


def lower(strings: jax.Array) -> jax.Array:
    is_upper = (strings >= 65) & (strings <= 90)
    return jnp.where(is_upper, strings + 32, strings)


def substring(strings: jax.Array, start: int, length: int) -> jax.Array:
    """Bytes [start, start+length) left-aligned into a fresh tensor."""
    L = strings.shape[-1]
    idx = jnp.arange(L) + start
    ok = idx < L
    got = jnp.take(strings, jnp.clip(idx, 0, L - 1), axis=-1)
    got = jnp.where(ok, got, _ZERO)
    keep = jnp.arange(L) < length
    return jnp.where(keep, got, _ZERO)


def strip_char(strings: jax.Array, char: str = " ") -> jax.Array:
    """Remove leading and trailing occurrences of ``char``."""
    c = jnp.uint8(ord(char))
    L = strings.shape[-1]
    is_c = strings == c
    is_nul = strings == 0
    body = ~is_c & ~is_nul
    any_body = jnp.any(body, axis=-1, keepdims=True)
    first = jnp.argmax(body, axis=-1)  # first non-char byte
    rev_last = jnp.argmax(jnp.flip(body, -1), axis=-1)
    last = L - 1 - rev_last
    idx = jnp.arange(L) + first[..., None]
    got = jnp.take_along_axis(strings, jnp.clip(idx, 0, L - 1), axis=-1)
    keep = (idx <= last[..., None]) & (idx < L)
    out = jnp.where(keep & any_body, got, _ZERO)
    return out


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def _match_at(strings: jax.Array, pattern: str) -> jax.Array:
    """(..., L) bool: does ``pattern`` occur starting at each byte position."""
    pat = T.encode_strings([pattern], max_len=max(len(pattern), 1))[0][: len(pattern)]
    L = strings.shape[-1]
    m = jnp.ones(strings.shape[:-1] + (L,), bool)
    for j, pb in enumerate(pat):
        idx = jnp.arange(L) + j
        ok = idx < L
        got = jnp.take(strings, jnp.clip(idx, 0, L - 1), axis=-1)
        m = m & jnp.where(ok, got == jnp.uint8(pb), False)
    return m


def contains(strings: jax.Array, pattern: str) -> jax.Array:
    return jnp.any(_match_at(strings, pattern), axis=-1)


def startswith(strings: jax.Array, pattern: str) -> jax.Array:
    return _match_at(strings, pattern)[..., 0]


def endswith(strings: jax.Array, pattern: str) -> jax.Array:
    lens = T.string_lengths(strings)
    pos = lens - len(pattern)
    m = _match_at(strings, pattern)
    got = jnp.take_along_axis(m, jnp.clip(pos, 0, m.shape[-1] - 1)[..., None], axis=-1)[
        ..., 0
    ]
    return got & (pos >= 0)


def replace_char(strings: jax.Array, old: str, new: str) -> jax.Array:
    return jnp.where(strings == jnp.uint8(ord(old)), jnp.uint8(ord(new)), strings)


# ---------------------------------------------------------------------------
# concat / split
# ---------------------------------------------------------------------------

def concat(parts: Sequence[jax.Array], separator: str = "", max_len: int = T.DEFAULT_MAX_LEN) -> jax.Array:
    """Join string columns with a separator (paper: StringConcatTransformer).

    The per-piece scatter advances via ``lax.scan`` over the stacked pieces
    (parts interleaved with separator constants, each zero-padded to a common
    width): one traced scatter step regardless of how many columns are
    joined, where the historical implementation unrolled parts × offsets.
    Step ops match that unrolled loop exactly — bit-exact, asserted by
    ``tests/test_scan_exact.py``."""
    lead = jnp.broadcast_shapes(*[p.shape[:-1] for p in parts])
    N = 1
    for d in lead:
        N *= d
    pieces = []
    if separator:
        sep_const = jnp.broadcast_to(
            jnp.asarray(T.encode_strings([separator], len(separator))[0]),
            (N, len(separator)),
        )
    for i, p in enumerate(parts):
        if i > 0 and separator:
            pieces.append(sep_const)
        pieces.append(jnp.broadcast_to(p, lead + p.shape[-1:]).reshape(N, p.shape[-1]))

    # common width: zero padding is invisible to the scatter (pad bytes are
    # invalid) and to the offset bump (string_lengths masks zeros)
    Lmax = max(p.shape[-1] for p in pieces)
    stacked = jnp.stack(
        [jnp.pad(p, ((0, 0), (0, Lmax - p.shape[-1]))) for p in pieces]
    )  # (P, N, Lmax)

    rows = jnp.arange(N)
    cols_base = jnp.arange(Lmax)

    def step(carry, p):
        out, offs = carry
        cols = offs[:, None] + cols_base[None, :]  # (N, Lmax)
        valid = (p != 0) & (cols < max_len)
        flat = rows[:, None] * max_len + jnp.clip(cols, 0, max_len - 1)
        flat = jnp.where(valid, flat, N * max_len)  # dropped
        out = out.at[flat.reshape(-1)].set(p.reshape(-1), mode="drop")
        offs = offs + T.string_lengths(p).astype(jnp.int64)
        return (out, offs), None

    init = (jnp.zeros((N * max_len,), jnp.uint8), jnp.zeros((N,), jnp.int64))
    (out, _), _ = jax.lax.scan(step, init, stacked)
    return out.reshape((N, max_len)).reshape(lead + (max_len,))


def split_to_list(
    strings: jax.Array,
    separator: str,
    list_length: int,
    default_value: Optional[str] = None,
    out_max_len: Optional[int] = None,
) -> jax.Array:
    """Split on a delimiter into a fixed-length padded list of strings.

    Output shape ``(..., list_length, out_max_len)``.  Missing / empty
    entries are filled with ``default_value`` (paper: defaultValue="PADDED").
    Greedy left-to-right non-overlapping delimiter matching.
    """
    d = len(separator)
    if d == 0:
        raise ValueError("separator must be non-empty")
    L = strings.shape[-1]
    ML = out_max_len or L
    lead = strings.shape[:-1]
    N = 1
    for x in lead:
        N *= x
    s = strings.reshape(N, L)

    raw = _match_at(s, separator)  # (N, L)

    if d == 1:
        # single-byte separator: occurrences can never overlap, so every raw
        # match IS a greedy start (the carry below degenerates to the
        # identity: after a match at q, cu = q+1 <= any later p) — skip the
        # L-step scan, which dominates split cost on CPU
        start = raw
    else:
        # Greedy non-overlap: sequential covered-until carry over the byte
        # axis, expressed as a scan so the trace does not unroll L steps.
        def carry_step(cu, xs):
            rawp, p = xs
            act = rawp & (p >= cu)
            cu = jnp.where(act, p + d, cu)
            return cu, act

        _, start_t = jax.lax.scan(
            carry_step,
            jnp.zeros((N,), jnp.int32),
            (jnp.moveaxis(raw, 1, 0), jnp.arange(L, dtype=jnp.int32)),
        )
        start = jnp.moveaxis(start_t, 0, 1)  # (N, L) actual delimiter starts

    # Materialise segments by GATHER, not scatter: XLA CPU scatters execute
    # element-at-a-time and dominated split cost.  Sorting the delimiter
    # positions (sentinel L for "none") gives, per segment k, its bounding
    # delimiters: segment k spans (pos[k-1] + d, pos[k]) — so output byte
    # (k, j) reads source position base_k + j, gated on staying inside the
    # segment.  Identical output bytes to the historical scatter: bytes are
    # placed at offset (p - segment start), zeros stay zeros (no compaction),
    # segments past the last delimiter / beyond list_length come out empty.
    idx = jnp.arange(L, dtype=jnp.int32)
    poss = jnp.sort(jnp.where(start, idx[None, :], L), axis=-1)  # (N, L)
    if L < list_length:
        poss = jnp.pad(poss, ((0, 0), (0, list_length - L)), constant_values=L)
    prev = jnp.concatenate(
        [jnp.full((N, 1), -d, poss.dtype), poss[:, : list_length - 1]], axis=1
    )
    base = prev + d  # (N, list_length): first source byte of each segment
    bound = poss[:, :list_length]  # (N, list_length): next delimiter (or L)
    p = base[:, :, None] + jnp.arange(ML, dtype=jnp.int32)[None, None, :]
    valid = (p < bound[:, :, None]) & (p < L)
    got = jnp.take_along_axis(
        s[:, None, :], jnp.clip(p, 0, L - 1).astype(jnp.int32), axis=-1
    )
    out = jnp.where(valid, got, _ZERO)
    if default_value is not None:
        dv = jnp.asarray(T.encode_strings([default_value], ML)[0])
        empty = jnp.all(out == 0, axis=-1)
        out = jnp.where(empty[..., None], dv, out)
    return out.reshape(lead + (list_length, ML))


# ---------------------------------------------------------------------------
# dates  (proleptic Gregorian; Howard Hinnant's civil algorithms in jnp)
# ---------------------------------------------------------------------------

def civil_from_days(days: jax.Array):
    """(year, month, day) from days since 1970-01-01."""
    z = days.astype(jnp.int64) + 719468
    era = jnp.where(z >= 0, z, z - 146096) // 146097
    doe = z - era * 146097
    yoe = (doe - doe // 1460 + doe // 36524 - doe // 146096) // 365
    y = yoe + era * 400
    doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
    mp = (5 * doy + 2) // 153
    day = doy - (153 * mp + 2) // 5 + 1
    month = jnp.where(mp < 10, mp + 3, mp - 9)
    year = jnp.where(month <= 2, y + 1, y)
    return year, month, day


def days_from_civil(year: jax.Array, month: jax.Array, day: jax.Array) -> jax.Array:
    y = jnp.where(month <= 2, year - 1, year).astype(jnp.int64)
    era = jnp.where(y >= 0, y, y - 399) // 400
    yoe = y - era * 400
    mp = jnp.where(month > 2, month - 3, month + 9)
    doy = (153 * mp + 2) // 5 + day - 1
    doe = yoe * 365 + yoe // 4 - yoe // 100 + doy
    return era * 146097 + doe - 719468


def weekday_from_days(days: jax.Array) -> jax.Array:
    """ISO weekday 1=Mon..7=Sun."""
    return ((days.astype(jnp.int64) + 3) % 7) + 1


def parse_date(strings: jax.Array) -> jax.Array:
    """Parse 'YYYY-MM-DD' (fixed positions) -> days since epoch (int64).

    Invalid rows (non-digits in digit positions) return -2**62.
    """

    def dig(i):
        c = strings[..., i].astype(jnp.int64)
        return c - 48, (c >= 48) & (c <= 57)

    total_ok = jnp.ones(strings.shape[:-1], bool)
    vals = []
    for pos in [(0, 1, 2, 3), (5, 6), (8, 9)]:
        v = jnp.zeros(strings.shape[:-1], jnp.int64)
        for i in pos:
            d, ok = dig(i)
            v = v * 10 + d
            total_ok = total_ok & ok
        vals.append(v)
    total_ok = (
        total_ok
        & (strings[..., 4] == jnp.uint8(ord("-")))
        & (strings[..., 7] == jnp.uint8(ord("-")))
    )
    days = days_from_civil(vals[0], vals[1], vals[2])
    return jnp.where(total_ok, days, jnp.int64(-(2**62)))
