"""PreprocessModel: the exported inference graph (the paper's Keras bundle).

A fitted pipeline exports to a flat node list ``(op_name, config, weights,
input_cols, output_cols)``.  The exported object

* evaluates as ONE pure jit-able function ``features -> features`` — exactly
  the property that let the paper fuse preprocessing into the serving graph
  and win 61% latency over pipeline-interpreting MLeap;
* performs dead-column elimination when ``outputs`` is given (serve only
  computes what the model consumes);
* serialises to a single zstd-compressed msgpack blob with NO pipeline /
  estimator / fit-engine dependencies — loading needs only this module and
  the stateless stage op registry (the analogue of "a generic Keras model
  without Kamae's package dependencies").
"""
from __future__ import annotations

import io
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard

from . import types as T
from .stage import STAGE_REGISTRY, stage_from_config

_FORMAT_VERSION = 1


def _pack_array(a) -> dict:
    a = np.asarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape), "data": a.tobytes()}


def _unpack_array(d) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(d["shape"])


class PreprocessModel:
    """Dependency-light, fusable inference preprocessing graph."""

    def __init__(self, nodes: List[dict]):
        # node: {op, config, weights: {name: array}, inputs, outputs}
        self.nodes = nodes
        self._stages = [
            stage_from_config(n["op"], n["config"], n["weights"]) for n in nodes
        ]
        self._jitted = None

    # -- construction --------------------------------------------------
    @classmethod
    def from_fitted(cls, fitted, outputs: Optional[Sequence[str]] = None):
        nodes = []
        for s in fitted.stages:
            nodes.append(
                {
                    "op": type(s.stage).__name__ if hasattr(s, "stage") else type(s).__name__,
                    "config": s.config(),
                    "weights": {k: v for k, v in s.weights().items()},
                    "inputs": list(s.input_names),
                    "outputs": list(s.output_names),
                }
            )
        if outputs is not None:
            nodes = _prune(nodes, set(outputs))
        return cls(nodes)

    # -- evaluation ------------------------------------------------------
    def __call__(self, features: T.Batch) -> T.Batch:
        b = dict(features)
        for s in self._stages:
            b = s.transform(b)
        return b

    def jit(self):
        """The fused single-XLA-program path (used by FusedModel)."""
        if self._jitted is None:
            self._jitted = jax.jit(self.__call__)
        return self._jitted

    @property
    def output_names(self) -> List[str]:
        out = []
        for n in self.nodes:
            out.extend(n["outputs"])
        return out

    # -- serialisation -----------------------------------------------------
    def save_bytes(self) -> bytes:
        payload = {
            "version": _FORMAT_VERSION,
            "nodes": [
                {
                    "op": n["op"],
                    "config": n["config"],
                    "weights": {k: _pack_array(v) for k, v in n["weights"].items()},
                    "inputs": n["inputs"],
                    "outputs": n["outputs"],
                }
                for n in self.nodes
            ],
        }
        raw = msgpack.packb(payload, use_bin_type=True)
        return zstandard.ZstdCompressor(level=9).compress(raw)

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            f.write(self.save_bytes())

    @classmethod
    def load_bytes(cls, blob: bytes) -> "PreprocessModel":
        raw = zstandard.ZstdDecompressor().decompress(blob)
        payload = msgpack.unpackb(raw, raw=False)
        if payload["version"] != _FORMAT_VERSION:
            raise ValueError(f"unsupported bundle version {payload['version']}")
        nodes = [
            {
                "op": n["op"],
                "config": n["config"],
                "weights": {k: jnp.asarray(_unpack_array(v)) for k, v in n["weights"].items()},
                "inputs": n["inputs"],
                "outputs": n["outputs"],
            }
            for n in payload["nodes"]
        ]
        return cls(nodes)

    @classmethod
    def load(cls, path: str) -> "PreprocessModel":
        with open(path, "rb") as f:
            return cls.load_bytes(f.read())


def _prune(nodes: List[dict], wanted: set) -> List[dict]:
    """Dead-column elimination: keep only nodes contributing to ``wanted``."""
    needed = set(wanted)
    keep = [False] * len(nodes)
    for i in range(len(nodes) - 1, -1, -1):
        if any(o in needed for o in nodes[i]["outputs"]):
            keep[i] = True
            needed.update(nodes[i]["inputs"])
    return [n for i, n in enumerate(nodes) if keep[i]]
