"""PreprocessModel: the exported inference graph (the paper's Keras bundle).

A fitted pipeline exports to a flat node list ``(op_name, config, weights,
input_cols, output_cols)``.  The exported object

* evaluates as ONE pure jit-able function ``features -> features`` — exactly
  the property that let the paper fuse preprocessing into the serving graph
  and win 61% latency over pipeline-interpreting MLeap;
* performs dead-column elimination when ``outputs`` is given (serve only
  computes what the model consumes);
* serialises to a single compressed blob with NO pipeline / estimator /
  fit-engine dependencies — loading needs only this module and the stateless
  stage op registry (the analogue of "a generic Keras model without Kamae's
  package dependencies").  The container is self-describing (``RPP1`` header
  + packer/codec flags): zstd+msgpack when available, stdlib zlib+json
  otherwise, so a bare-python serving host can still load bundles.
"""
from __future__ import annotations

import base64
import json
import zlib
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from . import types as T
from .stage import STAGE_REGISTRY, stage_from_config

_FORMAT_VERSION = 1

# Self-describing container header: magic + packer flag + codec flag.
# ``zstandard`` / ``msgpack`` are deliberately NOT imported at module scope —
# they are optional, and the stdlib fallbacks (zlib / json+base64) keep the
# bundle loadable on a bare-python serving host.  Legacy blobs (pre-header,
# raw zstd stream) are still recognised on load.
_MAGIC = b"RPP1"
_PACKER_MSGPACK = b"M"
_PACKER_JSON = b"J"
_CODEC_ZSTD = b"Z"
_CODEC_ZLIB = b"G"
_CODEC_RAW = b"R"


def _pack_array(a) -> dict:
    a = np.asarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape), "data": a.tobytes()}


def _unpack_array(d) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(d["shape"])


def _pack_payload(payload: dict) -> tuple:
    """(packer_flag, bytes) using msgpack when available, json+base64 else."""
    try:
        import msgpack

        return _PACKER_MSGPACK, msgpack.packb(payload, use_bin_type=True)
    except ImportError:
        def enc(o):
            if isinstance(o, bytes):
                return {"__b64__": base64.b64encode(o).decode("ascii")}
            if isinstance(o, dict):
                return {k: enc(v) for k, v in o.items()}
            if isinstance(o, (list, tuple)):
                return [enc(v) for v in o]
            return o

        return _PACKER_JSON, json.dumps(enc(payload)).encode("utf-8")


def _unpack_payload(flag: bytes, raw: bytes) -> dict:
    if flag == _PACKER_MSGPACK:
        import msgpack

        return msgpack.unpackb(raw, raw=False)
    if flag == _PACKER_JSON:
        def dec(o):
            if isinstance(o, dict):
                if set(o.keys()) == {"__b64__"}:
                    return base64.b64decode(o["__b64__"])
                return {k: dec(v) for k, v in o.items()}
            if isinstance(o, list):
                return [dec(v) for v in o]
            return o

        return dec(json.loads(raw.decode("utf-8")))
    raise ValueError(f"unknown packer flag {flag!r}")


def _compress(raw: bytes) -> tuple:
    try:
        import zstandard

        return _CODEC_ZSTD, zstandard.ZstdCompressor(level=9).compress(raw)
    except ImportError:
        return _CODEC_ZLIB, zlib.compress(raw, 6)


def _decompress(flag: bytes, body: bytes) -> bytes:
    if flag == _CODEC_ZSTD:
        import zstandard

        return zstandard.ZstdDecompressor().decompress(body)
    if flag == _CODEC_ZLIB:
        return zlib.decompress(body)
    if flag == _CODEC_RAW:
        return body
    raise ValueError(f"unknown codec flag {flag!r}")


class PreprocessModel:
    """Dependency-light, fusable inference preprocessing graph."""

    def __init__(
        self,
        nodes: List[dict],
        schedule: Optional[dict] = None,
        input_schema: Optional[Dict[str, dict]] = None,
    ):
        # node: {op, config, weights: {name: array}, inputs, outputs}
        self.nodes = nodes
        self._stages = [
            stage_from_config(n["op"], n["config"], n["weights"]) for n in nodes
        ]
        self._jitted = None
        # serialized TransformPlan schedule (cross-request plan persistence):
        # present on loaded bundles, so serving hosts skip plan analysis
        self._schedule = schedule
        # fit-time raw-column schema ({col: {dtype, shape}}): rides in the
        # bundle so the load-time verifier gate can prove the schedule is
        # executable on what the pipeline was actually fit on
        self.input_schema = input_schema
        self._plans: Dict[Optional[tuple], object] = {}

    # -- construction --------------------------------------------------
    @classmethod
    def from_fitted(cls, fitted, outputs: Optional[Sequence[str]] = None):
        nodes = []
        for s in fitted.stages:
            nodes.append(
                {
                    "op": type(s.stage).__name__ if hasattr(s, "stage") else type(s).__name__,
                    "config": s.config(),
                    "weights": {k: v for k, v in s.weights().items()},
                    "inputs": list(s.input_names),
                    "outputs": list(s.output_names),
                }
            )
        if outputs is not None:
            nodes = _prune(nodes, set(outputs))
        schema = getattr(fitted, "input_schema", None)
        if schema is not None:
            # restrict to raw columns the (possibly pruned) node list reads
            produced: set = set()
            needed: set = set()
            for n in nodes:
                needed.update(c for c in n["inputs"] if c not in produced)
                produced.update(n["outputs"])
            schema = {k: v for k, v in schema.items() if k in needed}
        return cls(nodes, input_schema=schema)

    # -- evaluation ------------------------------------------------------
    def __call__(self, features: T.Batch) -> T.Batch:
        b = dict(features)
        for s in self._stages:
            b = s.transform(b)
        return b

    def plan(self, outputs: Optional[Sequence[str]] = None, fuse: Optional[bool] = None):
        """Compile-once execution plan over the exported node list (see
        :mod:`repro.core.plan`): coercion/hash CSE + a persistent,
        sharding-aware jit cache.  Plans are cached per requested outputs;
        on a loaded bundle the full plan is rebuilt from the serialized
        schedule instead of re-running analysis.  ``fuse`` overrides the
        ``REPRO_FUSE_CHAINS`` chain-fusion default."""
        from .plan import TransformPlan

        key = (tuple(outputs) if outputs is not None else None, fuse)
        p = self._plans.get(key)
        if p is None:
            if key == (None, None) and self._schedule is not None:
                p = TransformPlan.from_schedule(self._stages, self._schedule)
            else:
                p = TransformPlan(self._stages, outputs=outputs, fuse=fuse)
            self._plans[key] = p
        return p

    def jit(self):
        """The fused single-XLA-program path (used by FusedModel).  Backed by
        a :class:`~repro.core.plan.TransformPlan`, so repeated calls with the
        same input signature never re-trace."""
        if self._jitted is None:
            self._jitted = self.plan()
        return self._jitted

    def stream(self, batches, engine=None, **runner_kwargs):
        """Offline bulk transform through the exported graph: one compiled
        executable, packed + double-buffered staging, optional mesh sharding
        (see :class:`~repro.core.runner.PlanRunner`)."""
        from .runner import PlanRunner

        return PlanRunner(self.plan(), engine=engine, **runner_kwargs).run(batches)

    @property
    def output_names(self) -> List[str]:
        out = []
        for n in self.nodes:
            out.extend(n["outputs"])
        return out

    # -- serialisation -----------------------------------------------------
    def save_bytes(self) -> bytes:
        schedule = self.plan().schedule()
        self._verify_gate(schedule, self.input_schema, "export save")
        payload = {
            "version": _FORMAT_VERSION,
            "nodes": [
                {
                    "op": n["op"],
                    "config": n["config"],
                    "weights": {k: _pack_array(v) for k, v in n["weights"].items()},
                    "inputs": n["inputs"],
                    "outputs": n["outputs"],
                }
                for n in self.nodes
            ],
            # plan schedule rides along so a serving host can rebuild the
            # TransformPlan without re-running liveness/CSE analysis on load
            "schedule": schedule,
            "input_schema": self.input_schema,
        }
        packer, raw = _pack_payload(payload)
        codec, body = _compress(raw)
        return _MAGIC + packer + codec + body

    @staticmethod
    def _verify_gate(schedule, input_schema, where: str) -> None:
        """Structural plan verification (no jax, no tracing): refuse to
        save/load a bundle whose schedule reads outside its recorded fit
        schema, references missing stages, resurrects freed buffers or
        never produces a declared output.  ``REPRO_ANALYZE_GATE=0``
        disables (forensics escape hatch)."""
        if schedule is None:
            return
        from repro.analyze import PlanSchemaError, plan_check  # noqa: F401

        if not plan_check.gate_enabled():
            return
        plan_check.verify_schedule_structure(
            schedule, input_schema=input_schema, where=where
        ).raise_if_errors(where)

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            f.write(self.save_bytes())

    @classmethod
    def load_bytes(cls, blob: bytes) -> "PreprocessModel":
        if blob[: len(_MAGIC)] == _MAGIC:
            packer = blob[4:5]
            codec = blob[5:6]
            raw = _decompress(codec, blob[6:])
            payload = _unpack_payload(packer, raw)
        else:  # legacy v1 blob: headerless zstd-compressed msgpack
            import msgpack
            import zstandard

            raw = zstandard.ZstdDecompressor().decompress(blob)
            payload = msgpack.unpackb(raw, raw=False)
        if payload["version"] != _FORMAT_VERSION:
            raise ValueError(f"unsupported bundle version {payload['version']}")
        nodes = [
            {
                "op": n["op"],
                "config": n["config"],
                "weights": {k: jnp.asarray(_unpack_array(v)) for k, v in n["weights"].items()},
                "inputs": n["inputs"],
                "outputs": n["outputs"],
            }
            for n in payload["nodes"]
        ]
        schedule = payload.get("schedule")
        input_schema = payload.get("input_schema")
        cls._verify_gate(schedule, input_schema, "export load")
        return cls(nodes, schedule=schedule, input_schema=input_schema)

    @classmethod
    def load(cls, path: str) -> "PreprocessModel":
        with open(path, "rb") as f:
            return cls.load_bytes(f.read())


def _prune(nodes: List[dict], wanted: set) -> List[dict]:
    """Dead-column elimination: keep only nodes contributing to ``wanted``."""
    needed = set(wanted)
    keep = [False] * len(nodes)
    for i in range(len(nodes) - 1, -1, -1):
        if any(o in needed for o in nodes[i]["outputs"]):
            keep[i] = True
            needed.update(nodes[i]["inputs"])
    return [n for i, n in enumerate(nodes) if keep[i]]
