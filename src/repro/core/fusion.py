"""Chain-fusion IR: lowering fusable pipeline stages into flat op programs.

The execution planner (:mod:`repro.core.plan`) schedules a fitted pipeline as
a list of stage nodes.  Inside one jitted program XLA already fuses what it
can, but the *plan* still dispatches stage objects one by one at trace time,
and on accelerators each stage boundary is a fusion decision XLA may or may
not take — the ETH tabular-preprocessing study (PAPERS.md) measures exactly
this stage-at-a-time execution leaving most of the available bandwidth
unused.  ``fuse_chains`` (in plan.py) collapses maximal runs of fusable
elementwise / row-local stages into ONE :class:`ChainProgram`, which executes
either as a single Pallas megakernel (``repro.kernels.fused_transform``, one
grid over row blocks, intermediates VMEM-resident) or as a single XLA-jitted
chain executor off-TPU.

This module owns the IR and the per-stage lowering rules:

* :class:`ChainOp` — one elementwise/row-local op: static params only, slots
  by name.  Every op kind replays the EXACT jnp semantics of the stage it
  was lowered from (same primitives, same dtype promotion), so a fused chain
  is bit-identical to the staged plan by construction — asserted by
  ``tests/test_fused_chain.py`` on the LTR and quickstart pipelines and by
  the fuzz leg in ``tests/test_fuzz_exact.py``.
* :class:`ChainProgram` — ordered ops + external input/output slots, fully
  JSON-serialisable (it rides inside the plan schedule in export bundles)
  with a stable :meth:`signature` used to key the tuned-config store.
* :func:`lower_node` — Stage -> [ChainOp] lowering, returning None for
  anything non-fusable (string machinery, shape-changing ops, vector
  weights, learned tables) so the plan falls back stage-by-stage.

Fusability that depends on runtime dtypes (e.g. a numeric cast applied to a
column that turns out to hold string bytes) cannot be decided at analysis
time; those ops raise :class:`ChainFallback` at trace time and the plan
replays the member stages unfused — bit-identity is never at risk.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from . import types as T

#: env knob: "0" disables the fusion pass (plans execute stage-by-stage).
FUSE_ENV = "REPRO_FUSE_CHAINS"

#: op kinds the Pallas megakernel implements; programs containing anything
#: else (or hash seeds >= 2**32, whose limb encoding needs the jnp fallback)
#: run only on the XLA chain executor.
KERNEL_OPS = frozenset(
    {
        "cast",
        "log",
        "exp",
        "power",
        "abs",
        "clip",
        "round",
        "scale",
        "std_score",
        "bucketize",
        "binary_const",
        "binary",
        "cmp_const",
        "cmp",
        "logical",
        "where",
        "is_null",
        "coalesce",
        "impute",
        "std_scale",
        "minmax_scale",
        "hash_index",
    }
)


class ChainFallback(Exception):
    """Raised at trace time when a chain op meets a runtime dtype it cannot
    replay exactly (e.g. numeric cast of a string column); the plan then
    executes the member stages unfused."""


@dataclasses.dataclass(frozen=True)
class ChainOp:
    kind: str
    inputs: Tuple[str, ...]
    output: str
    params: Tuple = ()

    def to_json(self):
        return [self.kind, list(self.inputs), self.output, list(self.params)]

    @classmethod
    def from_json(cls, d):
        kind, ins, out, params = d
        params = tuple(tuple(p) if isinstance(p, list) else p for p in params)
        return cls(kind, tuple(ins), out, params)


class ChainProgram:
    """An ordered elementwise/row-local op program over named slots.

    ``inputs`` are the external env columns read (in order), ``outputs`` the
    env columns the chain emits.  Slots written and last-read inside the
    chain never appear in ``outputs`` — they are the VMEM-resident
    intermediates the megakernel keeps on chip.
    """

    def __init__(self, ops: Sequence[ChainOp], inputs: Sequence[str], outputs: Sequence[str]):
        self.ops = list(ops)
        self.inputs = list(inputs)
        self.outputs = list(outputs)

    @property
    def kernel_ok(self) -> bool:
        for op in self.ops:
            if op.kind not in KERNEL_OPS:
                return False
            if op.kind == "hash_index" and not 0 <= int(op.params[1]) < 2**32:
                return False
        return True

    @property
    def kinds(self) -> List[str]:
        return [op.kind for op in self.ops]

    def signature(self) -> str:
        """Stable cross-process id for the tuned-config store: the op-kind
        chain plus a content hash of the full (kinds, params, wiring)."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        digest = hashlib.md5(blob).hexdigest()[:10]
        kinds = "-".join(self.kinds[:6])
        if len(self.ops) > 6:
            kinds += f"-x{len(self.ops)}"
        return f"{kinds}@{digest}"

    def to_json(self) -> dict:
        return {
            "ops": [op.to_json() for op in self.ops],
            "inputs": list(self.inputs),
            "outputs": list(self.outputs),
        }

    @classmethod
    def from_json(cls, d: dict) -> "ChainProgram":
        return cls([ChainOp.from_json(o) for o in d["ops"]], d["inputs"], d["outputs"])

    def __repr__(self):
        return f"ChainProgram({self.signature()}, ops={len(self.ops)}, ins={len(self.inputs)}, outs={len(self.outputs)})"


# ---------------------------------------------------------------------------
# stage -> [ChainOp] lowering
# ---------------------------------------------------------------------------


def _py(v):
    """JSON-safe Python scalar preserving int-vs-float (weak-type promotion
    in ops like ``x * multiplier`` depends on the Python type)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    item = getattr(v, "item", None)
    return item() if item is not None else v


def _scalar_weight(weights: Dict, key: str) -> Optional[float]:
    w = weights.get(key)
    if w is None:
        return None
    arr = jnp.asarray(w)
    if arr.shape != ():
        return None
    return float(arr)


def _vector_weight(weights: Dict, key: str) -> Optional[Tuple[float, ...]]:
    w = weights.get(key)
    if w is None:
        return None
    arr = jnp.asarray(w)
    if arr.ndim != 1:
        return None
    return tuple(float(v) for v in arr)


def _lower_stage(st, weights: Dict, ins: Tuple[str, ...], outs: Tuple[str, ...]):
    """[ChainOp] replaying ``st.apply(weights, ins) -> outs``, or None."""
    # local imports keep core.fusion free of transformer import cycles
    from .estimators import scalers as _sc
    from .transformers import logical as _lg
    from .transformers import math as _m
    from .transformers import string as _s

    (out,) = outs if len(outs) == 1 else (None,)
    if out is None:
        return None  # all fusable stages are single-output

    if isinstance(st, _m.LogTransformer):
        return [ChainOp("log", ins, out, (_py(st.alpha), _py(st.base)))]
    if isinstance(st, _m.ExpTransformer):
        return [ChainOp("exp", ins, out)]
    if isinstance(st, _m.PowerTransformer):
        return [ChainOp("power", ins, out, (_py(st.exponent),))]
    if isinstance(st, _m.AbsoluteValueTransformer):
        return [ChainOp("abs", ins, out)]
    if isinstance(st, _m.ClipTransformer):
        return [ChainOp("clip", ins, out, (_py(st.minValue), _py(st.maxValue)))]
    if isinstance(st, _m.RoundTransformer):
        if st.mode not in ("round", "floor", "ceil"):
            return None
        return [ChainOp("round", ins, out, (st.mode,))]
    if isinstance(st, _m.ScaleTransformer):
        return [ChainOp("scale", ins, out, (_py(st.multiplier), _py(st.offset)))]
    if isinstance(st, _m.StandardScoreTransformer):
        return [ChainOp("std_score", ins, out, (_py(st.mean), _py(st.std)))]
    if isinstance(st, _m.BucketizeTransformer):
        return [ChainOp("bucketize", ins, out, tuple(float(s) for s in st.splits))]
    if isinstance(st, _m.MathBinaryTransformer):
        if st.op not in _m._BINARY:
            return None
        if st.constant is not None:
            return [ChainOp("binary_const", ins, out, (st.op, _py(st.constant)))]
        if len(ins) != 2:
            return None
        return [ChainOp("binary", ins, out, (st.op,))]
    if isinstance(st, _lg.ComparisonTransformer):
        if st.op not in _lg._CMP:
            return None
        if st.constant is not None:
            return [ChainOp("cmp_const", ins, out, (st.op, _py(st.constant)))]
        if len(ins) != 2:
            return None
        return [ChainOp("cmp", ins, out, (st.op,))]
    if isinstance(st, _lg.LogicalTransformer):
        if st.op == "not":
            return [ChainOp("logical", ins, out, ("not",))] if len(ins) == 1 else None
        if st.op not in ("and", "or", "xor") or len(ins) != 2:
            return None
        return [ChainOp("logical", ins, out, (st.op,))]
    if isinstance(st, _lg.IfThenElseTransformer):
        return [ChainOp("where", ins, out)] if len(ins) == 3 else None
    if isinstance(st, _lg.IsNullTransformer):
        sent = None if st.intSentinel is None else int(st.intSentinel)
        return [ChainOp("is_null", ins, out, (sent,))]
    if isinstance(st, _lg.CoalesceTransformer):
        sent = None if st.intSentinel is None else int(st.intSentinel)
        return [ChainOp("coalesce", ins, out, (_py(st.fillValue), sent))]
    if isinstance(st, _s.HashIndexTransformer):
        return [
            ChainOp(
                "hash_index", ins, out, (int(st.numBins), int(st.seed), int(st.indexOffset))
            )
        ]
    if isinstance(st, _sc.ImputeEstimator):
        fill = _scalar_weight(weights, "fill")
        return None if fill is None else [ChainOp("impute", ins, out, (fill,))]
    if isinstance(st, _sc.QuantileBinEstimator):
        splits = _vector_weight(weights, "splits")
        return None if splits is None else [ChainOp("bucketize", ins, out, splits)]
    if isinstance(st, _sc.StandardScaleEstimator):
        mean, std = _scalar_weight(weights, "mean"), _scalar_weight(weights, "std")
        if mean is None or std is None:
            return None  # vector (featureSize) weights stay unfused
        return [ChainOp("std_scale", ins, out, (mean, std))]
    if isinstance(st, _sc.MinMaxScaleEstimator):
        lo, span = _scalar_weight(weights, "min"), _scalar_weight(weights, "span")
        if lo is None or span is None:
            return None
        return [ChainOp("minmax_scale", ins, out, (lo, span))]
    return None


def lower_node(stage_or_fitted, in_specs, out_cols) -> Optional[List[ChainOp]]:
    """Lower one scheduled plan node (stage + resolved coercion tokens) into
    chain ops, or None when the node is not statically fusable.

    Input coercion lowers to ``cast`` ops (numeric dtypes only — a "string"
    coercion needs the string widening machinery and stays unfused), and
    ``outputDtype`` lowers to a trailing ``cast`` — so the op list replays
    coerce -> apply -> coerce_out exactly as ``TransformPlan._execute`` does.
    """
    st = getattr(stage_or_fitted, "stage", stage_or_fitted)
    weights = stage_or_fitted.weights() if hasattr(stage_or_fitted, "weights") else {}

    if st.outputDtype is not None and st.outputDtype == "string":
        return None
    ops: List[ChainOp] = []
    slot_ins = []
    for i, (col, _ver, token) in enumerate(in_specs):
        if token is None:
            slot_ins.append(col)
            continue
        dtype = token[0]
        if dtype == "string":
            return None  # needs number_to_string / byte identity — unfusable
        tmp = f"__c{i}__{col}"
        ops.append(ChainOp("cast", (col,), tmp, (dtype,)))
        slot_ins.append(tmp)

    if st.outputDtype is not None:
        tmp_out = tuple(f"__o__{c}" for c in out_cols)
    else:
        tmp_out = tuple(out_cols)

    body = _lower_stage(st, weights, tuple(slot_ins), tmp_out)
    if body is None:
        return None
    ops.extend(body)
    if st.outputDtype is not None:
        for t, c in zip(tmp_out, out_cols):
            ops.append(ChainOp("cast", (t,), c, (st.outputDtype,)))
    return ops


def build_program(op_lists: Sequence[List[ChainOp]], emit: Sequence[str]) -> ChainProgram:
    """Assemble member op lists into one program.  ``emit`` is the ordered
    set of env columns the chain must output (member outputs that are still
    live outside the chain); everything else written stays internal."""
    ops: List[ChainOp] = [op for lst in op_lists for op in lst]
    written: set = set()
    inputs: List[str] = []
    for op in ops:
        for s in op.inputs:
            if s not in written and s not in inputs:
                inputs.append(s)
        written.add(op.output)
    missing = [c for c in emit if c not in written]
    if missing:
        raise ValueError(f"chain emits columns it never writes: {missing}")
    return ChainProgram(ops, inputs, list(emit))
