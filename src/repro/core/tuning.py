"""Preprocessing hyperparameter search (paper §2 "Keras Tuner support").

The paper fuses the exported preprocessing model with the neural model and
lets Keras Tuner search preprocessing hyperparameters (hash bins, embedding
dims, thresholds).  Here a search space is declared over stage constructor
kwargs; each trial re-instantiates + refits the pipeline and evaluates a
user metric (e.g. validation loss of the downstream model).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Any, Callable, Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Choice:
    name: str
    values: Sequence[Any]


@dataclasses.dataclass(frozen=True)
class IntLog:
    """Log-uniform integer range (e.g. numBins in 1k..1M)."""

    name: str
    lo: int
    hi: int

    def sample(self, rng: random.Random) -> int:
        return int(round(math.exp(rng.uniform(math.log(self.lo), math.log(self.hi)))))


@dataclasses.dataclass
class Trial:
    params: Dict[str, Any]
    score: float


class PreprocessingTuner:
    """Random / grid search over pipeline-builder hyperparameters.

    Args:
      build_pipeline: hp-dict -> Pipeline (unfitted).
      evaluate: (FittedPipeline, hp-dict) -> float score (lower is better).
    """

    def __init__(
        self,
        build_pipeline: Callable[[Dict[str, Any]], Any],
        evaluate: Callable[[Any, Dict[str, Any]], float],
        space: Sequence[Any],
        mode: str = "random",
        max_trials: int = 16,
        seed: int = 0,
    ):
        self.build_pipeline = build_pipeline
        self.evaluate = evaluate
        self.space = list(space)
        self.mode = mode
        self.max_trials = max_trials
        self.seed = seed
        self.trials: List[Trial] = []

    def _candidates(self):
        if self.mode == "grid":
            choices = [
                s.values if isinstance(s, Choice) else [s.lo, s.hi] for s in self.space
            ]
            names = [s.name for s in self.space]
            for combo in itertools.product(*choices):
                yield dict(zip(names, combo))
        else:
            rng = random.Random(self.seed)
            for _ in range(self.max_trials):
                hp = {}
                for s in self.space:
                    if isinstance(s, Choice):
                        hp[s.name] = rng.choice(list(s.values))
                    else:
                        hp[s.name] = s.sample(rng)
                yield hp

    def search(self, data, engine=None) -> Trial:
        best: Optional[Trial] = None
        for i, hp in enumerate(self._candidates()):
            if i >= self.max_trials:
                break
            pipe = self.build_pipeline(hp)
            fitted = pipe.fit(data, engine=engine)
            score = float(self.evaluate(fitted, hp))
            t = Trial(params=hp, score=score)
            self.trials.append(t)
            if best is None or t.score < best.score:
                best = t
        assert best is not None, "no trials ran"
        return best
