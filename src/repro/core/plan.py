"""TransformPlan — compile-once execution planner for fitted pipelines.

This is the repo's answer to the paper's headline production result: the 61%
serving-latency win came from replacing a pipeline-*interpreting* runtime
(MLeap walking stage objects per request) with ONE fused compiled graph (the
exported Keras bundle).  ``FittedPipeline.transform`` and
``PreprocessModel.__call__`` are exactly such interpreters — a Python loop
over per-stage dicts — and the naive fix (``jax.jit`` around the whole loop)
still pays the interpreter at every trace and re-traces per call when the jit
wrapper is rebuilt.  ``TransformPlan`` analyzes the stage graph ONCE and
produces a single cached, jit-compiled ``features -> features`` function.

Three optimisations are applied at plan time:

1. **Column liveness / dead-column elimination at transform time.**  When a
   set of requested ``outputs`` is given, stages that do not contribute are
   pruned (as export-time pruning already did), and — new here — intermediate
   columns are dropped from the carried environment as soon as the last
   reader has run.  Inside XLA this is what DCE would do anyway; in eager /
   debug execution and for donated buffers it bounds peak memory to the live
   set instead of the whole column history.

2. **Coercion and hash CSE.**  Interpreted execution re-runs
   ``Stage._coerce`` (``number_to_string`` / ``string_to_number``) per stage,
   and every indexer re-hashes the same byte column with ``fnv1a64``.  The
   plan keys each coercion by ``(column, version, inputDtype, maxLen)`` and
   each hash by ``(string-view key, seed)`` and computes it once, sharing the
   value across all consuming stages via the ``plan_hash_seeds`` /
   ``apply_hashed`` stage protocol.  XLA's own CSE would merge *identical*
   subgraphs after optimisation — but only after paying trace + HLO-build
   cost for every duplicate; plan-level CSE removes the duplicates before
   they are ever traced (measured by ``benchmarks/preprocessing.py`` as
   reduced trace time and HLO op count).

3. **Persistent, sharding-aware jit cache with optional buffer donation.**
   Compiled executables are cached for the lifetime of the plan, keyed on
   ``(in_shardings, donate)`` — and within each wrapper XLA's own cache keys
   on the input signature — so the SAME ``TransformPlan`` object serves the
   single-device serve path (FusedModel / MicroBatcher) and a pod-sharded
   offline sweep without re-analysis: :meth:`TransformPlan.jit_for` lowers
   with ``in_shardings`` from ``Engine.batch_sharding()`` when an engine /
   mesh is supplied.  ``donate=True`` additionally donates the input batch
   buffers to the executable.

The static schedule is serialisable (:meth:`TransformPlan.schedule` /
:meth:`TransformPlan.from_schedule`): the export bundle carries it so a
serving host skips plan analysis on load entirely.

Hashing inside the plan routes through :func:`repro.core.hashing.
fnv1a64_routed`, i.e. the Pallas ``bloom_hash`` kernel on TPU and the jnp
scan elsewhere — both bit-exact with the reference implementation.

Multi-batch streaming execution of a plan (double-buffered host→device
staging, batch packing, donation) lives in :mod:`repro.core.runner`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.obs import envknobs
from repro.obs import trace as obs_trace

from . import fusion, hashing, strops
from . import types as T


@dataclasses.dataclass
class _Node:
    """One scheduled stage with resolved static keys."""

    stage: object  # Transformer / FittedStage
    in_specs: List[tuple]  # (col, version, coerce_token) per input
    out_cols: List[str]
    hash_seeds: Optional[List[int]]  # seeds the stage can consume, or None
    dead_after: List[str]  # columns to drop from the env after this node
    stage_index: int = -1  # position in the plan's full stage list


@dataclasses.dataclass
class _FusedNode:
    """A maximal run of fusable nodes collapsed into one chain program.

    Executes as ONE call into :mod:`repro.kernels.fused_transform` (a single
    Pallas megakernel on the kernel backend, a single XLA-jitted chain
    executor elsewhere).  ``members`` keeps the original nodes for the
    trace-time fallback (a runtime dtype the program cannot replay exactly —
    see :class:`repro.core.fusion.ChainFallback`) and for serialisation.
    ``internal`` columns are produced and fully consumed inside the chain;
    they never enter the environment (on the kernel path they stay
    VMEM-resident)."""

    program: fusion.ChainProgram
    in_specs: List[tuple]  # (col, version, None) per external input
    out_cols: List[str]
    dead_after: List[str]
    internal: List[str]
    members: List[_Node]
    hash_seeds = None  # duck-typing with _Node (fused nodes never hash-CSE)


def _fuse_enabled(flag: Optional[bool]) -> bool:
    if flag is not None:
        return bool(flag)
    return envknobs.env_flag(fusion.FUSE_ENV, True)


def _try_lower_node(node: _Node, hash_refs: Dict[tuple, int]):
    """Chain ops for one node, or None when it must execute staged.

    Hash stages are fusable only when their (col, version, seed) hash is
    consumed by no other stage — a shared hash belongs to the plan's hash-CSE
    memo, and fusing one consumer would recompute it."""
    if node.hash_seeds is not None:
        for col, ver, _tok in node.in_specs:
            for seed in node.hash_seeds:
                if hash_refs.get((col, ver, seed), 0) > 1:
                    return None
    return fusion.lower_node(node.stage, node.in_specs, node.out_cols)


def _make_fused(run: List[Tuple[_Node, list]]) -> _FusedNode:
    members = [n for n, _ in run]
    produced: List[str] = []
    for m in members:
        for c in m.out_cols:
            if c not in produced:
                produced.append(c)
    dead_union: List[str] = []
    for m in members:
        for c in m.dead_after:
            if c not in dead_union:
                dead_union.append(c)
    # produced AND dead inside the chain -> never materialised in the env
    internal = [c for c in produced if c in dead_union]
    out_cols = [c for c in produced if c not in internal]
    program = fusion.build_program([ops for _, ops in run], emit=out_cols)
    spec_by_col: Dict[str, tuple] = {}
    for m in members:
        for c, v, _t in m.in_specs:
            spec_by_col.setdefault(c, (c, v, None))
    in_specs = [spec_by_col[c] for c in program.inputs]
    # an internal col that was ALSO an external input (overwritten in-chain,
    # dead in-chain) still has its pre-chain value in the env — pop it
    dead_after = [
        c for c in dead_union if c not in internal or c in program.inputs
    ]
    return _FusedNode(
        program=program,
        in_specs=in_specs,
        out_cols=out_cols,
        dead_after=dead_after,
        internal=internal,
        members=members,
    )


def _fuse_chains(nodes: List[_Node], hash_refs: Dict[tuple, int]) -> List[object]:
    """Greedily group maximal runs (>= 2) of consecutive fusable nodes."""
    out: List[object] = []
    run: List[Tuple[_Node, list]] = []

    def flush():
        if len(run) >= 2:
            out.append(_make_fused(run))
        else:
            out.extend(n for n, _ in run)
        run.clear()

    for node in nodes:
        ops = _try_lower_node(node, hash_refs)
        if ops is None:
            flush()
            out.append(node)
        else:
            run.append((node, ops))
    flush()
    return out


def _stage_of(s):
    """Underlying Stage (unwraps FittedStage) for protocol lookups."""
    return getattr(s, "stage", s)


def _coerce_token(stage) -> Optional[tuple]:
    st = _stage_of(stage)
    if st.inputDtype is None:
        return None
    return (st.inputDtype, st.maxLen)


def _prune_stages(stages: Sequence, outputs: Sequence[str]) -> List:
    needed = set(outputs)
    keep = [False] * len(stages)
    for i in range(len(stages) - 1, -1, -1):
        if any(o in needed for o in stages[i].output_names):
            keep[i] = True
            needed.update(stages[i].input_names)
    return [s for i, s in enumerate(stages) if keep[i]]


class TransformPlan:
    """A fitted stage list compiled into one cached ``batch -> batch`` fn.

    Args:
      stages: resolved stages (Transformers / FittedStages) in pipeline
        order — e.g. ``FittedPipeline.stages`` or ``PreprocessModel._stages``.
      outputs: if given, the plan computes exactly these columns (stages not
        contributing are pruned; intermediates die at their last use).  If
        None the plan returns the full environment — raw columns plus every
        stage output — matching ``FittedPipeline.transform`` bit-for-bit.
      donate: donate input batch buffers to the compiled executable.
    """

    def __init__(
        self,
        stages: Sequence,
        outputs: Optional[Sequence[str]] = None,
        donate: bool = False,
        fuse: Optional[bool] = None,
    ):
        self._stages = list(stages)
        self._outputs = list(outputs) if outputs is not None else None
        self._donate = donate
        self._fuse = _fuse_enabled(fuse)
        self._trace_count = 0
        self._seen_signatures: set = set()
        # compiled-wrapper cache: (in_shardings, donate) -> jax.jit wrapper.
        # Within each wrapper jax's own cache keys on the input signature, so
        # the effective executable key is (signature, mesh/shardings, donate).
        self._jit_cache: Dict[tuple, object] = {}
        self.built_from_schedule = False
        self._analyze()

    def _analyze(self) -> None:
        """Build the static schedule from the stage list (runs once per plan;
        a deserialized schedule skips this entirely — see from_schedule)."""
        indexed = list(enumerate(self._stages))
        if self._outputs is not None:
            kept = _prune_stages(self._stages, self._outputs)
            kept_ids = {id(s) for s in kept}
            indexed = [(i, s) for i, s in indexed if id(s) in kept_ids]

        # ---- static schedule: versions, coercion keys, hash seeds --------
        version: Dict[str, int] = {}
        nodes: List[_Node] = []
        coerce_refs: Dict[tuple, int] = {}
        hash_refs: Dict[tuple, int] = {}
        for idx, s in indexed:
            token = _coerce_token(s)
            in_specs = [(c, version.get(c, 0), token) for c in s.input_names]
            seeds = getattr(_stage_of(s), "plan_hash_seeds", lambda: None)()
            for spec in in_specs:
                if spec[2] is not None:
                    coerce_refs[spec] = coerce_refs.get(spec, 0) + 1
                if seeds is not None:
                    for k in seeds:
                        # canonical (col, version, seed): the static upper
                        # bound on runtime hash sharing (dtype-independent)
                        hk = (spec[0], spec[1], k)
                        hash_refs[hk] = hash_refs.get(hk, 0) + 1
            for c in s.output_names:
                version[c] = version.get(c, 0) + 1
            nodes.append(
                _Node(s, in_specs, list(s.output_names), seeds, [], stage_index=idx)
            )

        # ---- liveness: drop dead columns when outputs are constrained ----
        if self._outputs is not None:
            keep = set(self._outputs)
            last_use = {}
            for i, n in enumerate(nodes):
                for c, _, _ in n.in_specs:
                    last_use[c] = i
                for c in n.out_cols:
                    last_use[c] = max(last_use.get(c, i), i)
            for i, n in enumerate(nodes):
                n.dead_after = [
                    c for c, last in last_use.items() if last == i and c not in keep
                ]

        # ---- chain fusion: collapse maximal fusable runs -----------------
        if self._fuse:
            nodes = _fuse_chains(nodes, hash_refs)

        self._nodes = nodes
        # static CSE telemetry: how many recomputations the plan removed
        self.cse_stats = {
            "coerce_refs": sum(coerce_refs.values()),
            "coerce_unique": len(coerce_refs),
            "coerce_shared": sum(v - 1 for v in coerce_refs.values()),
            "hash_refs": sum(hash_refs.values()),
            "hash_unique": len(hash_refs),
            "hash_shared": sum(v - 1 for v in hash_refs.values()),
        }

    # ------------------------------------------------------------------
    # pure execution function (traced once per input signature)
    # ------------------------------------------------------------------
    def _execute(self, batch: T.Batch) -> T.Batch:
        self._trace_count += 1
        # instant marker in whatever trace is current: a re-trace during a
        # served request is exactly the latency cliff worth seeing
        obs_trace.get_recorder().event(
            "plan.trace", component="plan",
            attrs={"trace_count": self._trace_count, "stages": len(self._nodes)},
        )
        env = dict(batch)
        memo: Dict[tuple, jax.Array] = {}

        def coerced(stage, spec):
            col, ver, token = spec
            if token is None:
                return env[col]
            raw = env[col]
            if token[0] == "string":
                if T.is_string_col(raw):
                    return raw  # "string" coercion is identity on byte cols
                # numeric -> decimal-string widening: canonical key shared
                # with string_view(), so hash stages don't trace it twice
                key = ("str", col, ver, _stage_of(stage).maxLen)
            else:
                key = ("coerce", spec)
            v = memo.get(key)
            if v is None:
                v = stage._coerce(raw)
                memo[key] = v
            return v

        def string_view(stage, spec):
            """(canonical key, byte tensor) the stage would hash, or (None,
            None) when the hash path does not apply.  The key is canonical
            across stages — independent of each stage's coercion token — so
            e.g. a vocab indexer and a hash indexer reading the same string
            column share one fnv1a64 evaluation."""
            col, ver, token = spec
            raw = env[col]
            st = _stage_of(stage)
            if T.is_string_col(raw):
                # "string" coercion is identity on byte columns; a numeric
                # coercion would parse the string first — not a hash input
                if token is None or token[0] == "string":
                    return ("str", col, ver), raw
                return None, None
            if not (
                jnp.issubdtype(raw.dtype, jnp.integer)
                or jnp.issubdtype(raw.dtype, jnp.bool_)
            ):
                return None, None  # float column: stage handles it itself
            # numeric column: hash the decimal-string widening, either because
            # the stage coerces to string or because it stringifies internally
            if not (
                (token is not None and token[0] == "string")
                or getattr(st, "plan_hash_stringify", False)
            ):
                return None, None
            key = ("str", col, ver, st.maxLen)
            v = memo.get(key)
            if v is None:
                v = strops.number_to_string(raw, st.maxLen)
                memo[key] = v
            return key, v

        def hashed(strkey, sview, seed):
            key = ("hash", strkey, seed)
            h = memo.get(key)
            if h is None:
                h = hashing.fnv1a64_routed(sview, seed)
                memo[key] = h
            return h

        def run_fused(node: _FusedNode) -> None:
            from repro.kernels.fused_transform import ops as fused_ops

            ins = [env[c] for c, _, _ in node.in_specs]
            try:
                outs = fused_ops.execute_chain(node.program, ins)
            except fusion.ChainFallback:
                # a runtime dtype the program cannot replay exactly (e.g. a
                # numeric cast over a string column): execute the member
                # stages one by one — bit-identical to the unfused plan
                for m in node.members:
                    m_ins = tuple(coerced(m.stage, spec) for spec in m.in_specs)
                    m_outs = m.stage.apply(m.stage.weights(), m_ins)
                    m_outs = tuple(m.stage._coerce_out(o) for o in m_outs)
                    env.update(zip(m.out_cols, m_outs))
                for c in node.internal:
                    env.pop(c, None)
            else:
                env.update(zip(node.out_cols, outs))
            for c in node.dead_after:
                env.pop(c, None)

        for node in self._nodes:
            if isinstance(node, _FusedNode):
                run_fused(node)
                continue
            stage = node.stage
            ins = tuple(coerced(stage, spec) for spec in node.in_specs)

            outs = None
            if node.hash_seeds is not None:
                views = [string_view(stage, spec) for spec in node.in_specs]
                if all(k is not None for k, _ in views):
                    hashes = [
                        [hashed(k, sv, seed) for seed in node.hash_seeds]
                        for k, sv in views
                    ]
                    outs = stage.apply_hashed(stage.weights(), ins, hashes)
            if outs is None:
                outs = stage.apply(stage.weights(), ins)

            outs = tuple(stage._coerce_out(o) for o in outs)
            env.update(zip(node.out_cols, outs))
            for c in node.dead_after:
                env.pop(c, None)

        if self._outputs is None:
            return env
        return {k: env[k] for k in self._outputs}

    def required_inputs(self) -> Optional[List[str]]:
        """Raw input columns the scheduled nodes actually read, or None when
        the plan returns the full environment (every input column is then
        part of the output contract).  The streaming runner uses this to
        stage only live columns."""
        if self._outputs is None:
            return None
        produced: set = set()
        required: List[str] = []
        for n in self._nodes:
            for c, _, _ in n.in_specs:
                if c not in produced and c not in required:
                    required.append(c)
            produced.update(n.out_cols)
        # requested outputs that are raw passthrough columns stay required
        for c in self._outputs:
            if c not in produced and c not in required:
                required.append(c)
        return required

    # ------------------------------------------------------------------
    # schedule serialisation (cross-request plan persistence)
    # ------------------------------------------------------------------
    def schedule(self) -> dict:
        """The static schedule as a plain (msgpack/json-safe) dict.

        Stages are referenced by index into the plan's stage list, so a
        consumer holding the same stage list (e.g. a loaded PreprocessModel
        bundle) can rebuild the plan with :meth:`from_schedule` and skip
        analysis entirely.  Fused-chain nodes carry their op program plus the
        member node schedules (for the trace-time fallback)."""

        def node_json(n):
            if isinstance(n, _FusedNode):
                return {
                    "fused": n.program.to_json(),
                    "in_specs": [[c, v, None] for c, v, _ in n.in_specs],
                    "out_cols": list(n.out_cols),
                    "dead_after": list(n.dead_after),
                    "internal": list(n.internal),
                    "members": [node_json(m) for m in n.members],
                }
            return {
                "stage": n.stage_index,
                "in_specs": [
                    [c, v, list(t) if t is not None else None]
                    for c, v, t in n.in_specs
                ],
                "out_cols": list(n.out_cols),
                "hash_seeds": list(n.hash_seeds)
                if n.hash_seeds is not None
                else None,
                "dead_after": list(n.dead_after),
            }

        return {
            "outputs": self._outputs,
            "nodes": [node_json(n) for n in self._nodes],
            "cse_stats": dict(self.cse_stats),
        }

    @classmethod
    def from_schedule(cls, stages: Sequence, sched: dict, donate: bool = False):
        """Rebuild a plan from :meth:`schedule` output without re-analysis."""
        plan = cls.__new__(cls)
        plan._stages = list(stages)
        outs = sched.get("outputs")
        plan._outputs = list(outs) if outs is not None else None
        plan._donate = donate
        plan._fuse = _fuse_enabled(None)
        plan._trace_count = 0
        plan._seen_signatures = set()
        plan._jit_cache = {}

        def node_from(d):
            if "fused" in d:
                members = [node_from(m) for m in d["members"]]
                if not plan._fuse:
                    # kill switch honoured on loaded schedules too: expand
                    # the chain back into its member stage nodes.  Member
                    # dead_after is a subset of the chain's bookkeeping, so
                    # re-attach the chain-level drops to the last member.
                    members[-1].dead_after = sorted(
                        set(members[-1].dead_after)
                        | set(d["dead_after"])
                        | set(d["internal"])
                    )
                    return members
                return _FusedNode(
                    program=fusion.ChainProgram.from_json(d["fused"]),
                    in_specs=[(c, v, None) for c, v, _ in d["in_specs"]],
                    out_cols=list(d["out_cols"]),
                    dead_after=list(d["dead_after"]),
                    internal=list(d["internal"]),
                    members=members,
                )
            return _Node(
                stage=plan._stages[d["stage"]],
                in_specs=[
                    (c, v, tuple(t) if t is not None else None)
                    for c, v, t in d["in_specs"]
                ],
                out_cols=list(d["out_cols"]),
                hash_seeds=list(d["hash_seeds"])
                if d.get("hash_seeds") is not None
                else None,
                dead_after=list(d["dead_after"]),
                stage_index=d["stage"],
            )

        plan._nodes = []
        for d in sched["nodes"]:
            n = node_from(d)
            plan._nodes.extend(n) if isinstance(n, list) else plan._nodes.append(n)
        plan.cse_stats = dict(sched["cse_stats"])
        plan.built_from_schedule = True
        return plan

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    @property
    def fn(self):
        """The pure uncompiled function (for engine sharding wrappers or
        fusion into a larger jitted program)."""
        return self._execute

    def eager(self, batch: T.Batch) -> T.Batch:
        """Run uncompiled (op-by-op); liveness genuinely frees memory here."""
        return self._execute(batch)

    def signature(self, batch: T.Batch) -> tuple:
        return tuple(
            (k, tuple(v.shape), str(v.dtype)) for k, v in sorted(batch.items())
        )

    def jit_for(self, engine=None, in_shardings=None, donate: Optional[bool] = None):
        """The cached jit wrapper for one execution context.

        ``engine`` (an :class:`~repro.core.engine.Engine` with a mesh)
        supplies ``in_shardings`` from ``batch_sharding()``; alternatively
        pass ``in_shardings`` directly (a sharding, or pytree prefix of the
        batch).  Wrappers are cached on ``(in_shardings, donate)`` — a
        NamedSharding hashes its mesh, so the same plan serves an unsharded
        single-device call and any number of mesh-sharded contexts, each
        compiled at most once per input signature, with zero re-analysis.

        The cache holds strong references: every distinct mesh used with
        this plan pins its NamedSharding + compiled wrapper for the plan's
        lifetime.  Bounded in practice (hosts use one or two meshes); a
        process churning through many throwaway meshes against one
        long-lived plan should create throwaway plans instead."""
        if donate is None:
            donate = self._donate
        if engine is not None and engine.mesh is not None and in_shardings is None:
            in_shardings = engine.batch_sharding()
        key = (in_shardings, donate)
        fn = self._jit_cache.get(key)
        if fn is None:
            obs_trace.get_recorder().event(
                "plan.jit_cache_miss", component="plan",
                attrs={"donate": bool(donate), "sharded": in_shardings is not None},
            )
            kwargs = {}
            if in_shardings is not None:
                kwargs["in_shardings"] = in_shardings
            fn = jax.jit(
                self._execute,
                donate_argnums=(0,) if donate else (),
                **kwargs,
            )
            self._jit_cache[key] = fn
        return fn

    def __call__(self, batch: T.Batch, engine=None) -> T.Batch:
        self._seen_signatures.add(self.signature(batch))
        return self.jit_for(engine=engine)(batch)

    def lower(self, batch: T.Batch):
        """Lower (trace) against ``batch`` without executing — used by the
        benchmarks for trace-time and HLO-op-count measurements."""
        return jax.jit(self._execute).lower(batch)

    # ------------------------------------------------------------------
    # chain fusion introspection / autotune warmup
    # ------------------------------------------------------------------
    @property
    def fused_chain_count(self) -> int:
        return sum(1 for n in self._nodes if isinstance(n, _FusedNode))

    @property
    def fusion_stats(self) -> dict:
        fused = [n for n in self._nodes if isinstance(n, _FusedNode)]
        return {
            "fused_chains": len(fused),
            "fused_stages": sum(len(n.members) for n in fused),
            "fused_ops": sum(len(n.program.ops) for n in fused),
        }

    def warm_fused(self, batch: T.Batch) -> dict:
        """Autotune every fused chain against ``batch`` (one EAGER pass with
        tuning enabled, so chain dispatch sees concrete arrays and can time
        candidate block configs).  Winners persist in the tuned-config store;
        a cache hit performs zero sweeps.  No-op when the plan has no fused
        chains or the kernel backend is not active; returns tuner stats."""
        from repro.kernels.fused_transform import tune

        if not self.fused_chain_count or not tune.kernel_route():
            return tune.stats()
        with tune.tuning():
            self._execute(dict(batch))
        return tune.stats()

    @property
    def stats(self) -> dict:
        return {
            "n_stages": len(self._nodes),
            "trace_count": self._trace_count,
            "signatures_seen": len(self._seen_signatures),
            "jit_cache_entries": len(self._jit_cache),
            **self.cse_stats,
            **self.fusion_stats,
        }

    def __repr__(self) -> str:
        outs = "all" if self._outputs is None else len(self._outputs)
        return (
            f"TransformPlan(stages={len(self._nodes)}, outputs={outs}, "
            f"coerce_shared={self.cse_stats['coerce_shared']}, "
            f"hash_shared={self.cse_stats['hash_shared']})"
        )


def hlo_op_count(lowered) -> int:
    """Rough HLO/StableHLO op count of a ``jax.jit(...).lower(...)`` result —
    the graph-size metric the benchmarks report alongside trace time."""
    text = lowered.as_text()
    return sum(1 for line in text.splitlines() if " = " in line)
