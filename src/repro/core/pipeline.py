"""Pipeline graph: chaining stages, multi-pass streaming fit, export.

Mirrors the paper's ``KamaeSparkPipeline``: stages declare input/output
columns, forming a DAG over the columnar batch.  ``fit`` streams over the
dataset the *minimal* number of passes: every pass fits all estimators whose
inputs are already computable (Spark instead re-scans per stage — a
beyond-paper improvement that matters when the fit engine is a TPU pod
reading from a data lake).

The fitted pipeline exports one-to-one into a :class:`~repro.core.export.
PreprocessModel` — the JAX analogue of ``build_keras_model`` in Listing 1.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from . import types as T
from .stage import Estimator, FittedStage, Stage, Transformer

DataLike = Union[T.Batch, Callable[[], Iterable[T.Batch]]]


def _as_batch_factory(data: DataLike) -> Callable[[], Iterable[T.Batch]]:
    if isinstance(data, dict):
        return lambda: iter([data])
    return data


class Pipeline:
    """An ordered collection of stages (order must be topologically valid,
    as in Spark)."""

    def __init__(self, stages: Sequence[Stage]):
        self.stages = list(stages)
        self._validate()

    def _validate(self):
        names = set()
        for s in self.stages:
            if not s.input_names:
                raise ValueError(f"stage {s.name} declares no inputs")
            if not s.output_names:
                raise ValueError(f"stage {s.name} declares no outputs")
            for o in s.output_names:
                if o in names:
                    raise ValueError(f"duplicate output column {o!r}")
                names.add(o)

    # ------------------------------------------------------------------
    def fit(self, data: DataLike, engine=None) -> "FittedPipeline":
        """Fit all estimators by streaming over ``data``.

        ``engine`` (see :mod:`repro.core.engine`) supplies the mesh/sharding
        context; None fits on the default device.
        """
        factory = _as_batch_factory(data)
        resolved: List[Optional[object]] = [
            s if isinstance(s, (Transformer, FittedStage)) or not s.needs_fit else None
            for s in self.stages
        ]

        # One cached peek discovers the raw column names; availability in
        # every later pass derives from these names plus stage metadata, and
        # the peeked batch is chained back into the first streaming pass — so
        # a one-epoch data factory is not consumed one extra batch per pass.
        # An all-transformer pipeline never touches the data at all.
        raw_cols: frozenset = frozenset()
        leftover: Optional[Iterable[T.Batch]] = None
        input_schema: Optional[Dict[str, dict]] = None
        if any(r is None for r in resolved):
            peek_iter = iter(factory())
            try:
                first_batch = next(peek_iter)
            except StopIteration:
                raise ValueError("data factory yielded no batches")
            raw_cols = frozenset(first_batch.keys())
            leftover = itertools.chain([first_batch], peek_iter)
            # record the fit-time schema of the raw columns the stages
            # actually read: the static verifier gates export bundles and
            # registry entries against it (offline/online skew detection)
            produced: set = set()
            needed: set = set()
            for s in self.stages:
                needed.update(n for n in s.input_names if n not in produced)
                produced.update(s.output_names)
            from repro.analyze.plan_check import schema_of_batch

            input_schema = {
                k: v
                for k, v in schema_of_batch(first_batch).items()
                if k in needed
            }

        n_passes = 0
        while any(r is None for r in resolved):
            n_passes += 1
            if n_passes > len(self.stages) + 1:
                raise RuntimeError("pipeline fit did not converge (cyclic columns?)")
            # estimators fittable this pass: all inputs TRANSITIVELY
            # producible from raw columns through already-resolved stages
            pending: Dict[int, Estimator] = {}
            available = set(raw_cols)
            for i, s in enumerate(self.stages):
                if resolved[i] is not None and all(n in available for n in s.input_names):
                    available.update(s.output_names)
                elif resolved[i] is None and all(n in available for n in s.input_names):
                    pending[i] = s
            if not pending:
                raise RuntimeError("no estimator became fittable; check column names")

            stats = {i: e.init_stats() for i, e in pending.items()}
            prefix = [
                (i, r) for i, r in enumerate(resolved) if r is not None
            ]

            def pass_step(stats_in, batch):
                b = dict(batch)
                for _, r in prefix:
                    # transformers downstream of still-unfitted estimators
                    # cannot run yet — their inputs appear in a later pass
                    if all(n in b for n in r.input_names):
                        b = r.transform(b)
                out = {}
                for i, e in pending.items():
                    ins = tuple(e._coerce(b[n]) for n in e.input_names)
                    out[i] = e.update_stats(stats_in[i], ins)
                return out

            step = engine.jit_fit_step(pass_step) if engine is not None else jax.jit(pass_step)
            batches = leftover if leftover is not None else factory()
            leftover = None
            for batch in batches:
                stats = step(stats, batch)
            for i, e in pending.items():
                resolved[i] = FittedStage(e, e.finalize(jax.device_get(stats[i])))

        return FittedPipeline(
            self, resolved, n_passes=n_passes, input_schema=input_schema
        )

    # Spark parity alias ------------------------------------------------
    def getStages(self):
        return self.stages


#: Paper-API alias so Listing-1-style code ports verbatim.
KamaeSparkPipeline = Pipeline


class FittedPipeline:
    """All stages resolved; behaves like a Spark PipelineModel."""

    def __init__(
        self,
        pipeline: Pipeline,
        resolved: Sequence[object],
        n_passes: int = 0,
        input_schema: Optional[Dict[str, dict]] = None,
    ):
        self.pipeline = pipeline
        self.stages = list(resolved)
        self.n_passes = n_passes
        # fit-time raw-column schema ({col: {dtype, shape}}), None when the
        # pipeline was all-transformer (fit never saw data)
        self.input_schema = input_schema
        self._plans: Dict[tuple, object] = {}

    def transform(self, batch: T.Batch) -> T.Batch:
        """Interpreted reference path (one XLA dispatch per op)."""
        b = dict(batch)
        for s in self.stages:
            b = s.transform(b)
        return b

    def plan(
        self,
        outputs: Optional[Sequence[str]] = None,
        donate: bool = False,
        fuse: Optional[bool] = None,
    ):
        """Compile-once execution plan (see :mod:`repro.core.plan`): dead
        columns eliminated, coercions/hashes CSE'd, executables cached
        per (signature, shardings, donate) on the plan itself.  ``fuse``
        overrides the ``REPRO_FUSE_CHAINS`` chain-fusion default (the
        benchmarks pin ``fuse=False`` plans to measure the staged baseline)."""
        from .plan import TransformPlan

        key = (tuple(outputs) if outputs is not None else None, donate, fuse)
        p = self._plans.get(key)
        if p is None:
            p = TransformPlan(self.stages, outputs=outputs, donate=donate, fuse=fuse)
            self._plans[key] = p
        return p

    def transform_jit(self, batch: T.Batch, engine=None) -> T.Batch:
        """Compiled transform.  Routed through the plan's sharding-aware jit
        cache: the SAME plan (and analysis) serves unsharded calls and any
        number of engine meshes, each lowered with ``in_shardings`` from
        ``Engine.batch_sharding()`` and compiled once per signature."""
        return self.plan()(batch, engine=engine)

    def transform_stream(self, batches, engine=None, **runner_kwargs):
        """Streaming offline transform: drive a whole batch iterator through
        one compiled plan with packed, double-buffered host→device staging
        and donated buffers (see :class:`~repro.core.runner.PlanRunner`).
        Yields one output batch per input batch."""
        from .runner import PlanRunner

        return PlanRunner(self.plan(), engine=engine, **runner_kwargs).run(batches)

    # ------------------------------------------------------------------
    def export(self, outputs: Optional[Sequence[str]] = None):
        """Export to a dependency-light inference graph (paper:
        ``build_keras_model``)."""
        from .export import PreprocessModel

        return PreprocessModel.from_fitted(self, outputs=outputs)

    # Spark parity alias
    def build_keras_model(self, tf_input_schema=None, outputs=None):
        """Paper-API alias for :meth:`export`; the schema argument is accepted
        for source compatibility and used only for validation."""
        return self.export(outputs=outputs)
