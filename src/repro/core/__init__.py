"""repro.core — the paper's contribution: Spark→Keras preprocessing parity,
re-based onto JAX (the paper's own named future-work backend).

Public API mirrors the paper's:

    from repro.core import KamaeSparkPipeline, StringIndexEstimator, ...
    pipe = KamaeSparkPipeline(stages=[...])
    fitted = pipe.fit(batches)            # distributed via Engine(mesh)
    model = fitted.build_keras_model()    # -> PreprocessModel (pure JAX)
"""
from . import hashing, sketches, strops
from . import types
from .engine import Engine
from .export import PreprocessModel
from .pipeline import FittedPipeline, KamaeSparkPipeline, Pipeline
from .plan import TransformPlan
from .runner import PlanRunner
from .stage import Estimator, FittedStage, Stage, Transformer
from .estimators import (
    ImputeEstimator,
    MinMaxScaleEstimator,
    OneHotEncodeEstimator,
    QuantileBinEstimator,
    SharedStringIndexEstimator,
    StandardScaleEstimator,
    StringIndexEstimator,
)
from .transformers import *  # noqa: F401,F403 — the transformer suite
from .transformers import __all__ as _transformer_names

__all__ = [
    "types",
    "strops",
    "hashing",
    "sketches",
    "Engine",
    "PreprocessModel",
    "Pipeline",
    "KamaeSparkPipeline",
    "FittedPipeline",
    "TransformPlan",
    "PlanRunner",
    "Stage",
    "Transformer",
    "Estimator",
    "FittedStage",
    "StringIndexEstimator",
    "SharedStringIndexEstimator",
    "OneHotEncodeEstimator",
    "StandardScaleEstimator",
    "MinMaxScaleEstimator",
    "ImputeEstimator",
    "QuantileBinEstimator",
] + list(_transformer_names)
