"""Root pytest conftest: dependency gating for optional test-time packages.

The container intentionally ships a minimal environment; ``hypothesis`` may be
absent.  Rather than skipping whole test modules (they contain plenty of
non-property tests too), we install a small deterministic shim implementing
the subset of the hypothesis API this suite uses (``given`` / ``settings`` /
``strategies.{integers,floats,text,characters,lists,sampled_from}``).  The
shim draws pseudo-random examples from a seed derived from the test name, so
runs are reproducible.  When the real hypothesis is installed it is used
unchanged.
"""
import importlib.util
import os
import sys

try:  # pragma: no cover - prefer the real package when present
    import hypothesis  # noqa: F401
except ImportError:
    _path = os.path.join(os.path.dirname(__file__), "tests", "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
