"""Paper §3 end to end: the Expedia-style Learning-to-Rank search-filters
flow — fit the ~30-stage Kamae pipeline on synthetic search logs, train a
listwise ranking head on the transformed features, fuse preprocessing + model
into one serving bundle, and compare fused vs unfused latency.

Run:  PYTHONPATH=src python examples/ltr_search_filters.py [--steps 60]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.apps.ltr_pipeline import build_ltr_pipeline
from repro.data import ltr_rows
from repro.serve import FusedModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--rows", type=int, default=1024)
    args = ap.parse_args()

    # 1. fit the preprocessing pipeline on the "data lake" extract -----------
    train = ltr_rows(args.rows, seed=0)
    fitted, feature_cols = build_ltr_pipeline(train)
    print(f"pipeline fitted in {fitted.n_passes} streaming pass(es); "
          f"features: {feature_cols}")

    transformed = fitted.transform(train)
    feats = jnp.stack(
        [transformed[c].astype(jnp.float32) for c in feature_cols], axis=-1
    )  # (Q, L, F)
    labels = transformed["label_click"]

    # 2. train a listwise ranking head on preprocessed features -------------
    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.normal(0, 0.1, (feats.shape[-1], 64)), jnp.float32),
        "w2": jnp.asarray(rng.normal(0, 0.1, (64, 1)), jnp.float32),
    }

    def score(params, x):
        h = jax.nn.relu(jnp.einsum("qlf,fh->qlh", x, params["w1"]))
        return jnp.einsum("qlh,ho->qlo", h, params["w2"])[..., 0]

    def loss_fn(params, x, y):
        s = score(params, x)  # listwise softmax CE on clicked items
        logp = jax.nn.log_softmax(s, axis=-1)
        return -jnp.mean(jnp.sum(y * logp, axis=-1) / jnp.maximum(y.sum(-1), 1))

    @jax.jit
    def step(params, x, y):
        l, g = jax.value_and_grad(loss_fn)(params, x, y)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g), l

    losses = []
    for i in range(args.steps):
        params, l = step(params, feats, labels)
        losses.append(float(l))
    print(f"ranking loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {args.steps} steps")
    assert losses[-1] < losses[0]

    # 3. fuse pipeline + model into one serving bundle -----------------------
    def model_fn(params, f):
        x = jnp.stack([f[c].astype(jnp.float32) for c in feature_cols], axis=-1)
        return score(params, x)

    # donate=False: this script re-submits the same request arrays below; the
    # serve tier (MicroBatcher) keeps the donating default instead
    fm = FusedModel(fitted.export(outputs=feature_cols), model_fn, params, donate=False)
    request = {k: v[:4] for k, v in ltr_rows(8, seed=42).items()}
    request.pop("label_click")
    scores = fm(request)
    print("serving scores (4 queries x 16 items):", np.asarray(scores)[:, :4].round(3))

    def timed(fn, n=10):
        fn(request)
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(request)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e3

    t_fused, t_unfused = timed(fm), timed(fm.call_unfused)
    print(f"fused {t_fused:.2f} ms vs unfused {t_unfused:.2f} ms "
          f"(-{100*(1-t_fused/t_unfused):.0f}%; paper reports -61% vs MLeap)")


if __name__ == "__main__":
    main()
