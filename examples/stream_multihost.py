"""Multi-host streaming + serving demo on fake CPU devices.

Runs the SAME logical work twice — one process, then a 2-process job via the
fake-device launcher (``tests/multihost.py``: N subprocesses, each with its
own jax runtime, sharing a coordinator address) — and shows:

* per-host shard feeding: each process of the 2-process job stages and
  computes only its row block of every superbatch (``PlanRunner`` with a
  ``ProcessMesh``), and concatenating the blocks reproduces the 1-process
  stream bit-for-bit;
* cross-process serving: process 0 runs the whole ServingGateway and routes
  each formed batch's row blocks to the shard worker, which executes its
  FusedModel shard via ``jit_for`` — replies are bit-identical to a
  single-process gateway and nothing traces after warmup.

Run:  PYTHONPATH=src python examples/stream_multihost.py
"""
import os
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tests"))

from multihost import launch  # noqa: E402  (the fake-device launcher)


def main() -> None:
    sizes = [64, 64, 48, 64]
    payload = {"seed": 11, "sizes": sizes, "pack": 2}

    print("== offline stream: per-host shard feeding ==")
    ref = launch("stream_plan", 1, payload)[0]
    parts = launch("stream_plan", 2, payload)
    for p, r in enumerate(parts):
        print(
            f"  process {p}: staged+computed {r['stats']['local_rows']} of "
            f"{sum(sizes)} rows in {r['stats']['superbatches']} superbatches"
        )
    mismatches = 0
    for i in range(len(sizes)):
        for k in ref["outputs"][i]:
            joined = np.concatenate([p["outputs"][i][k] for p in parts], axis=0)
            if not np.array_equal(ref["outputs"][i][k], joined):
                mismatches += 1
    print(f"  bit-identical to the 1-process stream: {mismatches == 0}")

    print("== online serving: cross-process gateway routing ==")
    replay = {"seed": 12, "requests": 32, "buckets": (2, 4, 8), "max_batch": 8}
    ref_gw = launch("gateway_replay", 1, replay)[0]
    coord, worker = launch("gateway_replay", 2, replay)
    same = all(
        np.array_equal(a, b) for a, b in zip(ref_gw["results"], coord["results"])
    )
    print(
        f"  coordinator completed {coord['stats']['completed']}/{replay['requests']} "
        f"requests across {coord['shards']} processes "
        f"(worker executed {worker['batches']} shard batches)"
    )
    print(f"  e2e p50 {coord['e2e_us']['p50_us']}us; per-shard round-trips: "
          + ", ".join(f"{k} p50={v.get('p50_us')}us" for k, v in coord["shard_us"].items()))
    print(f"  traces after warmup: {coord['traces_since_warmup']} (AOT held across processes)")
    print(f"  bit-identical to the 1-process gateway: {same}")


if __name__ == "__main__":
    main()
