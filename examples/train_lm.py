"""End-to-end LM training driver on the framework substrate: checkpointed,
heartbeat-monitored, straggler-tracked training of an assigned-architecture
config.

CPU demo (default, ~2M params, a few hundred steps in minutes):
    PYTHONPATH=src python examples/train_lm.py

~100M-param run (the pod-scale recipe; CPU-hours on this container, minutes
on one v5e host):
    PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hundred-m", action="store_true",
                    help="full-width 12-layer (~100M) instead of smoke scale")
    args = ap.parse_args()

    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--ckpt-dir", "/tmp/repro_lm_ckpt",
        "--ckpt-every", "100",
        "--heartbeat", "/tmp/repro_lm_hb.json",
    ]
    if not args.hundred_m:
        argv.append("--smoke")
    losses = train_main(argv)
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
