"""Static plan verification — catching offline/online schema skew BEFORE
the first request.

Fits a small pipeline, then demonstrates the three analyzer surfaces:

1. ``verify_plan`` proves (by abstract interpretation — nothing executes)
   that the staged AND fused plans are executable on the fit-time schema
   and that every fused chain is dtype/shape-equivalent to its staged
   members.
2. The export-bundle gate: a bundle whose recorded fit schema is
   deliberately mismatched with its schedule is REFUSED at load with a
   typed ``PlanSchemaError`` instead of failing (or silently mis-binding
   columns) at first execute.
3. The registry gate: registering a servable with an example row whose
   dtype kind disagrees with the fit schema raises at ``register`` time.

Run:  PYTHONPATH=src python examples/analyze_pipeline.py
"""
import numpy as np
import jax.numpy as jnp

from repro.analyze import PlanSchemaError, plan_check
from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    PreprocessModel,
    StringIndexEstimator,
    StringToStringListTransformer,
)
from repro.core import types as T
from repro.core.plan import TransformPlan


def build():
    rng = np.random.default_rng(7)
    n = 256
    batch = {
        "UserID": jnp.asarray(rng.integers(1, 5000, n), jnp.int32),
        "Genres": jnp.asarray(
            T.encode_strings(rng.choice(["Action|Comedy", "Drama"], n), 32)
        ),
        "Price": jnp.asarray(rng.lognormal(3, 2, n), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="UserID", outputCol="UserID_indexed",
                inputDtype="string", numBins=10000,
            ),
            StringToStringListTransformer(
                inputCol="Genres", outputCol="Genres_split", separator="|",
                listLength=4, defaultValue="PADDED",
            ),
            StringIndexEstimator(
                inputCol="Genres_split", outputCol="Genres_indexed",
                numOOVIndices=1, maskToken="PADDED",
            ),
            LogTransformer(inputCol="Price", outputCol="Price_log", alpha=1.0),
        ]
    )
    return pipe.fit(batch), batch


def main():
    fitted, batch = build()

    # 1. Verify the plans without executing anything ----------------------
    for fuse in (False, True):
        plan = TransformPlan(fitted.stages, fuse=fuse)
        rep = plan_check.verify_plan(plan, example=batch)
        mode = "fused" if fuse else "staged"
        print(f"verify_plan[{mode}]: {rep!r}")
        assert rep.ok()

    # The fit-time schema the gates check against, recorded by fit():
    print("recorded fit schema:")
    for col, spec in sorted(fitted.input_schema.items()):
        print(f"  {col}: {spec['dtype']} trailing={spec['shape']}")

    # 2. Export gate: a deliberately mismatched bundle is refused ---------
    model = fitted.export()
    blob_ok = model.save_bytes()
    PreprocessModel.load_bytes(blob_ok)
    print("healthy bundle: save + load pass the gate")

    # Forge skew: drop a column the schedule reads from the recorded
    # schema (in production this is the offline/online drift case — the
    # serving side's feature store no longer provides what fit saw).
    # Serialising the skewed artifact needs the gate off; the LOAD gate
    # then refuses it with file:line-grade findings.
    import os

    model.input_schema = {
        k: v for k, v in model.input_schema.items() if k != "Price"
    }
    os.environ["REPRO_ANALYZE_GATE"] = "0"
    blob_skewed = model.save_bytes()
    del os.environ["REPRO_ANALYZE_GATE"]
    try:
        PreprocessModel.load_bytes(blob_skewed)
    except PlanSchemaError as e:
        print(f"skewed bundle REFUSED at load: {e.findings[0].message}")
    else:
        raise AssertionError("the gate should have refused the skewed bundle")

    # 3. Registry gate: mismatched example row refused at register -------
    from repro.serve.gateway.registry import ModelRegistry

    reg = ModelRegistry()
    good_row = {k: np.asarray(v)[0] for k, v in batch.items()}
    reg.register("prices", fitted.export(), good_row, buckets=(1, 4))
    print("matching example row: registered")

    bad_row = dict(good_row)
    bad_row["Price"] = np.int64(3)  # fit on float32 — a dtype-KIND flip
    try:
        ModelRegistry().register("prices", fitted.export(), bad_row, buckets=(1, 4))
    except PlanSchemaError as e:
        print(f"mismatched example REFUSED at register: {e.findings[0].message}")
    else:
        raise AssertionError("the gate should have refused the skewed example")


if __name__ == "__main__":
    main()
