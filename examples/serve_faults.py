"""Fault-tolerant serving demo: kill a shard worker mid-traffic, lose nothing.

Runs the SAME seeded gateway traffic twice — one process (the reference),
then a 2-process routed gateway whose shard worker is SIGKILLed mid-stream
(after its 4th batch, past warmup) — and shows the fault-tolerant executor's
contract:

* the coordinator detects the death (EOF on the reply socket), rebuilds the
  row-block table over the survivors via ``ProcessMesh.degraded``, and
  re-executes the lost in-flight block locally;
* every request still completes — zero client-surfaced failures — and the
  results are BIT-IDENTICAL to the 1-process run (recovery re-executes the
  same row blocks through the same bit-stable program);
* the ``ft`` snapshot records what happened: deaths, reshards, recovered
  blocks, and the detection-to-first-degraded-answer latency.

A second schedule delays every reply from the worker instead of killing it:
the straggler monitor flags it and the coordinator hedges its blocks with a
local re-execution — first answer wins, nobody dies.

Run:  PYTHONPATH=src python examples/serve_faults.py
"""
import os
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tests"))

from multihost import launch  # noqa: E402  (the fake-device launcher)


def main() -> None:
    base = {
        "seed": 13,
        "requests": 40,
        "buckets": (2, 4, 8),
        "max_batch": 8,
        "heartbeat_s": 0.5,
        "cost_model": False,
        "traffic": "stream",
        "clients": 3,
    }
    ref = launch("gateway_chaos", 1, base, devices_per_proc=1)[0]

    print("== kill -9 mid-stream: degraded-mesh resharding ==")
    kill = dict(
        base, faults=[{"process": 1, "type": "kill", "after_batches": 4}]
    )
    coord = launch(
        "gateway_chaos", 2, kill, devices_per_proc=1, expendable=[1]
    )[0]
    ft = coord["ft"]
    same = all(
        np.array_equal(a, b) for a, b in zip(coord["results"], ref["results"])
    )
    print(
        f"  completed {coord['completed']}/{base['requests']} requests, "
        f"client-surfaced failures: {coord['worker_failed']}"
    )
    print(
        f"  worker deaths={ft['worker_deaths']} reshards={ft['reshards']} "
        f"recovered_blocks={ft.get('recovered_blocks', 0)} "
        f"(cause: {ft['death_reasons'].get('process1', '?')})"
    )
    print(
        f"  batches served through the degraded mesh: "
        f"{coord['stage_counts']['execute_reshard']}; detection-to-answer "
        f"{ft.get('kill_recover_ms', 0):.1f}ms"
    )
    print(f"  bit-identical to the 1-process gateway: {same}")

    print("== straggling worker: flagged and hedged around ==")
    slow = dict(
        base,
        hedge=True,
        faults=[
            {"process": 1, "type": "delay", "delay_s": 0.35, "batches": (0, 1 << 30)}
        ],
    )
    coord = launch("gateway_chaos", 2, slow, devices_per_proc=1)[0]
    ft = coord["ft"]
    same = all(
        np.array_equal(a, b) for a, b in zip(coord["results"], ref["results"])
    )
    print(
        f"  completed {coord['completed']}/{base['requests']} requests; "
        f"flagged={ft['flagged']} hedges={ft.get('hedges', 0)} "
        f"hedge_wins={ft.get('hedge_wins', 0)} "
        f"busy_skips={ft.get('busy_skips', 0)}"
    )
    print(
        f"  hedged batches: {coord['stage_counts']['execute_hedge']}; "
        f"deaths: {ft['worker_deaths'] if 'worker_deaths' in ft else 0} "
        f"(a slow worker is routed around, never killed)"
    )
    print(f"  bit-identical to the 1-process gateway: {same}")


if __name__ == "__main__":
    main()
