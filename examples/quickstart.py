"""Quickstart — the paper's Listing 1 (MovieLens pipeline), ported verbatim.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    OneHotEncodeEstimator,
    PreprocessModel,
    StringIndexEstimator,
    StringToStringListTransformer,
)
from repro.core import types as T
from repro.data import movielens_rows


def main():
    train_ml = movielens_rows(4096, seed=0)

    user_hash_indexer = HashIndexTransformer(
        inputCol="UserID",
        outputCol="UserID_indexed",
        # Set the inputDtype to force the id to be a string
        inputDtype="string",
        # Set 10k bins to reduce collisions
        numBins=10000,
        layerName="user_hash_indexer",
    )
    movie_id_string_indexer = StringIndexEstimator(
        inputCol="MovieID",
        outputCol="MovieID_indexed",
        inputDtype="string",
        # Order the collected labels by descending frequency
        stringOrderType="frequencyDesc",
        numOOVIndices=1,
        layerName="movie_id_string_indexer",
    )
    occupation_one_hot_encoder = OneHotEncodeEstimator(
        inputCol="Occupation",
        outputCol="Occupation_indexed",
        stringOrderType="frequencyDesc",
        inputDtype="string",
        numOOVIndices=1,
        # Whether the one hot encoder should drop the index for unseen.
        dropUnseen=True,
        layerName="occupation_one_hot_encoder",
    )
    genres_split_to_array_transform = StringToStringListTransformer(
        inputCol="Genres",
        outputCol="Genres_split",
        separator="|",
        # Max number of genres for a movie is 6
        listLength=6,
        # If a list does not have 6 it will be padded
        defaultValue="PADDED",
        layerName="genres_split_to_array_transform",
    )
    genres_string_indexer = StringIndexEstimator(
        # Input is the output of the splitting
        inputCol="Genres_split",
        outputCol="Genres_indexed",
        stringOrderType="frequencyDesc",
        numOOVIndices=1,
        # Mask the PADDED token to send this to the 0 index
        maskToken="PADDED",
        layerName="genres_string_indexer",
    )
    pipeline = KamaeSparkPipeline(
        stages=[
            user_hash_indexer,
            movie_id_string_indexer,
            occupation_one_hot_encoder,
            genres_split_to_array_transform,
            genres_string_indexer,
        ]
    )
    fit_pipeline = pipeline.fit(train_ml)
    input_schema = [
        dict(name="UserID", dtype="int32", shape=(1,)),
        dict(name="MovieID", dtype="int32", shape=(1,)),
        dict(name="Occupation", dtype="int32", shape=(1,)),
        dict(name="Genres", dtype="string", shape=(1,)),
    ]
    keras_model = fit_pipeline.build_keras_model(tf_input_schema=input_schema)

    # --- serve-side: identical outputs from the exported model --------------
    request = {k: v[:8] for k, v in movielens_rows(16, seed=7).items()}
    offline = fit_pipeline.transform(request)
    online = keras_model(request)
    for k in offline:
        np.testing.assert_allclose(
            np.asarray(offline[k]), np.asarray(online[k]), rtol=1e-6
        )
    print("offline/online parity: OK")

    keras_model.save("/tmp/movielens_preprocess.kamae")
    restored = PreprocessModel.load("/tmp/movielens_preprocess.kamae")
    again = restored(request)
    np.testing.assert_array_equal(
        np.asarray(online["Genres_indexed"]), np.asarray(again["Genres_indexed"])
    )
    print("bundle round-trip: OK")
    print("Genres_indexed sample:\n", np.asarray(online["Genres_indexed"][:3]))
    print("Occupation one-hot shape:", online["Occupation_indexed"].shape)

    # --- chain fusion: planned vs fused transform timings -------------------
    # Listing 1 is string-op heavy (indexers don't fuse); numeric feature
    # chains are where the fusion pass collapses stage boundaries.
    import time

    import jax

    from repro.core import (
        BucketizeTransformer,
        ClipTransformer,
        LogTransformer,
        ScaleTransformer,
    )

    rng = np.random.default_rng(0)
    n = 4096
    num_batch = {
        "price": jnp.asarray(rng.lognormal(3.0, 2.0, n), jnp.float32),
        "nights": jnp.asarray(rng.integers(1, 30, n), jnp.int32),
    }
    fuse_pipe = KamaeSparkPipeline(
        stages=[
            LogTransformer(inputCol="price", outputCol="price_log", alpha=1.0),
            ScaleTransformer(
                inputCol="price_log", outputCol="price_s", multiplier=0.5, offset=-1.0
            ),
            BucketizeTransformer(
                inputCol="price_s", outputCol="price_bin", splits=[0.5, 1.5, 2.5]
            ),
            ClipTransformer(
                inputCol="nights", outputCol="nights_c", minValue=1, maxValue=14
            ),
        ]
    ).fit(num_batch)
    planned = fuse_pipe.plan(fuse=False)
    fused = fuse_pipe.plan(fuse=True)

    def us_per_call(plan, iters=20, reps=5):
        jax.block_until_ready(list(plan(num_batch).values()))  # compile
        best = float("inf")
        for _ in range(reps):  # best-of-reps rides out scheduler noise
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(list(plan(num_batch).values()))
            best = min(best, (time.perf_counter() - t0) / iters * 1e6)
        return best

    t_planned, t_fused = us_per_call(planned), us_per_call(fused)
    out_p, out_f = planned(num_batch), fused(num_batch)
    for k in out_p:
        np.testing.assert_array_equal(np.asarray(out_p[k]), np.asarray(out_f[k]))
    print(
        f"chain fusion: planned {t_planned:.1f}us/call vs fused {t_fused:.1f}us/call "
        f"({fused.fused_chain_count} fused chains, bit-identical outputs)"
    )


if __name__ == "__main__":
    main()
