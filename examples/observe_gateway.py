"""Observability demo: a traced gateway request end-to-end, then every obs
surface on the one run — the stitched span tree in the terminal, the
flattened metrics snapshot, a forced flight dump, and a Chrome/Perfetto
trace export you can drop straight into https://ui.perfetto.dev (or
``chrome://tracing``).

Run:  PYTHONPATH=src python examples/observe_gateway.py
Then: python -m repro.obs.report /tmp/observe_gateway_trace.json
"""
import concurrent.futures as cf

import numpy as np
import jax.numpy as jnp

from repro.core import KamaeSparkPipeline, LogTransformer, StandardScaleEstimator
from repro.obs import export as obs_export
from repro.obs import flight as obs_flight
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import snapshot as obs_snapshot
from repro.obs import trace as obs_trace
from repro.serve import FusedModel, ServingGateway

TRACE_PATH = "/tmp/observe_gateway_trace.json"


def build_model() -> FusedModel:
    rng = np.random.default_rng(0)
    lake = {"price": jnp.asarray(rng.lognormal(3, 1, 512), jnp.float32)}
    pipe = KamaeSparkPipeline(
        stages=[
            LogTransformer(inputCol="price", outputCol="pl", alpha=1.0),
            StandardScaleEstimator(inputCol="pl", outputCol="ps"),
        ]
    )
    export = pipe.fit(lake).export(outputs=["ps"])

    def fwd(params, feats):
        return feats["ps"] * params["w"]

    return FusedModel(export, fwd, {"w": jnp.float32(0.5)})


def main() -> None:
    # a fresh, always-sampling recorder so the demo is self-contained
    rec = obs_trace.TraceRecorder(capacity=4096, enabled=True, sample=1.0)
    obs_trace.set_recorder(rec)

    gw = ServingGateway(max_pending=64, max_wait_ms=2.0, workers=2)
    gw.register(
        "ranker", build_model(), example={"price": np.float32(25.0)},
        buckets=(1, 2, 4, 8), max_batch=8,
    )
    gw.warmup()

    rng = np.random.default_rng(7)
    with cf.ThreadPoolExecutor(8) as pool:
        futs = [
            pool.submit(
                gw.submit, "ranker",
                {"price": np.float32(rng.lognormal(3, 1))}, timeout=30.0,
            )
            for _ in range(16)
        ]
        for f in futs:
            f.result()

    # 1. the span trees, straight from the ring
    tuples = [s.as_tuple() for s in rec.spans()]
    requests = [t for t in tuples if t[3] == "request"]
    print(f"== {len(requests)} traced requests, {rec.recorded} spans ==")
    one = [t for t in tuples if t[0] == requests[-1][0]]
    print(obs_report.format_trace_tree(one))

    # 2. the one top-level snapshot (instruments + gateway source + trace/env)
    snap = obs_snapshot()
    gws = snap["sources"]["gateway"]["stats"]
    print("\n== obs.snapshot() ==")
    print(f"completed={gws['completed']} batches={gws['batches']} "
          f"rows={gws['rows']} ring={snap['trace']['in_ring']} spans")

    # 3. a flight dump, forced (normally a fault triggers this)
    dump = obs_flight.get_flight().trigger(
        "demo", component="example", attrs={"note": "forced for the demo"},
        force=True,
    )
    print(f"\n== flight dump: {len(dump['spans'])} spans frozen ==")

    # 4. Perfetto/Chrome export
    obs_export.write_chrome_trace(TRACE_PATH, tuples)
    print(f"\nwrote {TRACE_PATH} — load it at https://ui.perfetto.dev,")
    print(f"or render it here: python -m repro.obs.report {TRACE_PATH}")

    gw.close()
    print("\n-- metrics (flattened) --")
    print(obs_metrics.render_text(snap))


if __name__ == "__main__":
    main()
