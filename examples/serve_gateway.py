"""Serving-gateway demo: TWO fused models (a ranker and a CTR head) behind
one ServingGateway — the paper's production shape (a request-serving chassis
around the fused preprocessing+model artifact), with admission control,
deadline-aware continuous batching, finish-time-feasible shedding (the
warmup probe seeds a per-(model, bucket) execute-time cost model, so a
request whose budget cannot cover the estimated execute time is shed with
``InfeasibleDeadlineError`` instead of being served late), and DDSketch
latency telemetry.

Run:  PYTHONPATH=src python examples/serve_gateway.py
"""
import concurrent.futures as cf
import json

import numpy as np
import jax.numpy as jnp

from repro.core import (
    HashIndexTransformer,
    KamaeSparkPipeline,
    LogTransformer,
    StandardScaleEstimator,
)
from repro.serve import (
    DeadlineExceededError,
    FusedModel,
    InfeasibleDeadlineError,
    ServingGateway,
)


def build_ranker() -> FusedModel:
    """Hash-indexed user id + log/scaled price, fused with a tiny head."""
    rng = np.random.default_rng(0)
    lake = {
        "user_id": jnp.asarray(rng.integers(1, 1_000_000, 512), jnp.int64),
        "price": jnp.asarray(rng.lognormal(3, 2, 512), jnp.float32),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="user_id", outputCol="uh", inputDtype="string",
                numBins=1024,
            ),
            LogTransformer(inputCol="price", outputCol="pl", alpha=1.0),
            StandardScaleEstimator(inputCol="pl", outputCol="ps"),
        ]
    )
    export = pipe.fit(lake).export(outputs=["uh", "ps"])

    def fwd(params, feats):
        return feats["ps"] * params["w"] + (feats["uh"] % 7)

    return FusedModel(export, fwd, {"w": jnp.float32(0.3)}, donate=True)


def build_ctr() -> FusedModel:
    """A second, independent model: log-dwell-time -> click-through score."""
    rng = np.random.default_rng(1)
    lake = {"dwell_ms": jnp.asarray(rng.lognormal(6, 1, 512), jnp.float32)}
    pipe = KamaeSparkPipeline(
        stages=[LogTransformer(inputCol="dwell_ms", outputCol="ld", alpha=1.0)]
    )
    export = pipe.fit(lake).export(outputs=["ld"])

    def fwd(params, feats):
        return 1.0 / (1.0 + jnp.exp(-(feats["ld"] * params["a"] + params["b"])))

    return FusedModel(
        export, fwd, {"a": jnp.float32(0.8), "b": jnp.float32(-5.0)}, donate=True
    )


def main():
    gw = ServingGateway(max_pending=128, max_wait_ms=2.0, workers=2)
    gw.register(
        "ranker",
        build_ranker(),
        example={"user_id": np.int64(42), "price": np.float32(99.5)},
        buckets=(1, 2, 4, 8, 16),
        max_batch=16,
    )
    gw.register(
        "ctr",
        build_ctr(),
        example={"dwell_ms": np.float32(1500.0)},
        buckets=(1, 2, 4, 8),
        max_batch=8,
    )
    print("warmup (AOT precompile every model x bucket):", gw.warmup())

    rng = np.random.default_rng(7)

    def client(i):
        """Mixed traffic: mostly ranker, some CTR; interactive requests get
        priority 1 + a 200 ms deadline, batch traffic gets neither — and a
        few requests carry a 1.5 ms budget below the ~3 ms execute estimate,
        which the cost model sheds as INFEASIBLE instead of serving late
        (or as expired, if the budget runs out while queued)."""
        try:
            if i % 7 == 1:
                return gw.submit(
                    "ctr",
                    {"dwell_ms": np.float32(rng.lognormal(6, 1))},
                    priority=1,
                    deadline_ms=1.5,  # cannot finish: shed, never served late
                )
            if i % 3 == 0:
                return gw.submit(
                    "ctr",
                    {"dwell_ms": np.float32(rng.lognormal(6, 1))},
                    priority=1,
                    deadline_ms=200.0,
                )
            return gw.submit(
                "ranker",
                {
                    "user_id": np.int64(rng.integers(1, 1_000_000)),
                    "price": np.float32(rng.lognormal(3, 2)),
                },
                priority=0,
            )
        except InfeasibleDeadlineError:
            return "INFEASIBLE"  # cost model: could never have finished
        except DeadlineExceededError:
            return "SHED"  # budget ran out while queued

    with cf.ThreadPoolExecutor(max_workers=32) as pool:
        outs = list(pool.map(client, range(200)))

    served = sum(1 for o in outs if not isinstance(o, str))
    infeasible = sum(1 for o in outs if o == "INFEASIBLE")
    shed = sum(1 for o in outs if o == "SHED")
    print(
        f"served {served}/200 requests "
        f"({infeasible} shed as infeasible, {shed} shed as expired)"
    )
    snap = gw.snapshot()
    print("execute-time estimates (ms) per (model, bucket), + rows→time fit:")
    for name in ("ranker", "ctr"):
        cost = snap["models"][name]["cost"]
        ests = {b: rec["est_ms"] for b, rec in cost.items() if b != "fit"}
        fit = cost.get("fit", {})
        print(f"  {name}: " + json.dumps(ests)
              + f"  fit: {fit.get('slope_ms_per_row')} ms/row + {fit.get('intercept_ms')} ms")
    print(json.dumps(snap, indent=2, default=str))
    gw.close()
    print("OK")


if __name__ == "__main__":
    main()
