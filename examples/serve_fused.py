"""Serving demo: batched greedy decode with a KV cache, behind a Kamae
preprocessing frontend that turns RAW request features (string user ids,
dates) into model-ready tensors inside the same process — the paper's
deployment shape applied to an LM.

Run:  PYTHONPATH=src python examples/serve_fused.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import (
    DatePartTransformer,
    HashIndexTransformer,
    KamaeSparkPipeline,
    StringToDateTransformer,
)
from repro.core import types as T
from repro.models import registry
from repro.serve import greedy_decode


def main():
    # --- request-metadata preprocessing (fit once, export) ------------------
    rng = np.random.default_rng(0)
    lake = {
        "user_id": jnp.asarray(rng.integers(1, 10_000_000, 256), jnp.int64),
        "request_date": jnp.asarray(
            T.encode_strings(["2026-07-12"] * 256, 12)
        ),
    }
    pipe = KamaeSparkPipeline(
        stages=[
            HashIndexTransformer(
                inputCol="user_id", outputCol="user_bucket",
                inputDtype="string", numBins=1024,
            ),
            StringToDateTransformer(inputCol="request_date", outputCol="days"),
            DatePartTransformer(inputCol="days", outputCol="weekday", part="weekday"),
        ]
    )
    frontend = pipe.fit(lake).export()

    # --- LM backbone ----------------------------------------------------------
    cfg = configs.get("codeqwen1_5_7b").smoke()
    model = registry.build(cfg)
    params = model.init(0)

    # --- a batch of requests ---------------------------------------------------
    request = {
        "user_id": lake["user_id"][:4],
        "request_date": lake["request_date"][:4],
    }
    meta = frontend(request)
    # user bucket conditions the prompt (e.g. personalised system prefix)
    prompts = (meta["user_bucket"][:, None] % cfg.vocab).astype(jnp.int32)
    prompts = jnp.tile(prompts, (1, 8))

    out = greedy_decode(model, params, prompts, steps=16, max_len=64)
    print("request user buckets:", np.asarray(meta["user_bucket"]))
    print("request weekday:", np.asarray(meta["weekday"]))
    print("generated tokens:\n", np.asarray(out))
    assert out.shape == (4, 16)
    print("OK")


if __name__ == "__main__":
    main()
